//! End-to-end engine benchmark.
//!
//! Always runs the hermetic **multi-learner engine sweep** on the synthetic
//! FC workload (NativeMlp, no artifacts): learner counts 1/4/16, sequential
//! (threads=1) vs parallel (threads=0 = auto), `--exchange barrier` vs the
//! layer-streamed overlap pipeline, plus isolated pack/exchange timings —
//! and emits machine-readable `BENCH_engine.json` so future PRs have a perf
//! trajectory to regress against. Per row: wall steps/sec, the simulated
//! step time of the streamed pipeline (`sim_step_s`) against the barrier
//! placement (`sim_step_barrier_s`, same measured compute + serialized
//! comm) and a `projected_speedup` column (overlapped+compressed vs
//! dense/barrier — the paper's compression rates as step-time wins). All
//! runs are asserted bit-identical across thread counts AND exchange modes
//! (the engine's determinism contract). A `staleness_sweep` (16 learners,
//! K ∈ {0,1,2} × jitter ∈ {0, 0.3}) reports what the bounded-staleness
//! window buys under straggler skew (`sim_step_s`, `stall_s`,
//! `projected_speedup` per row) and asserts K=2 strictly beats the
//! synchronous schedule at jitter 0.3. A `churn_sweep` (8 learners, mixed
//! fail/join/leave schedule plus a matched fail-vs-leave pair) reports the
//! per-event recovery cost of a membership epoch — `rebuild_s`,
//! `drain_stall_s`, and the residual L1 mass lost (fail) or handed over
//! (leave). A `pool` entry records the
//! persistent worker pool's per-step constant next to what the retired
//! per-step `thread::scope` spawn used to cost. A char-LSTM row (the
//! paper's recurrent workload on the native layer-graph backend) rides
//! along under the `char_lstm` key.
//!
//! A `controller_sweep` (16 learners, jitter 0.3, streamed ring) pits the
//! adaptive control plane (`--controller on`, starting synchronous)
//! against the hand-tuned static staleness grid K ∈ {0, 1, 2}: the
//! controller's full-run simulated step time must strictly beat the worst
//! static point and its steady-state marginal step time must match the
//! best one, while its decision timeline stays bit-identical across
//! thread counts and exchange modes. Written to `BENCH_controller.json`;
//! `--fast` runs only this sweep (the CI controller gate).
//!
//! With `--features pjrt` it additionally reports the per-model Algorithm-1
//! breakdown over the AOT artifacts (skips models that are missing).
//!
//!   cargo bench --bench bench_step [-- --fast]

use adacomp::comm::{topology, Fabric, LinkModel};
use adacomp::compress::{self, Config, Kind, Packet};
use adacomp::data::synth::GaussianMixture;
use adacomp::models::Layout;
use adacomp::optim::LrSchedule;
use adacomp::runtime::native::NativeMlp;
use adacomp::train::{Engine, TrainConfig};
use adacomp::util::json::{self, Json};
use adacomp::util::rng::Pcg32;
use adacomp::util::timer::{fmt_ns, time_n, Stats, Stopwatch};

const DIMS: &[usize] = &[128, 256, 10];
const BATCH: usize = 32;
const STEPS: usize = 40;

fn engine_cfg(learners: usize, threads: usize, exchange: &str, topology: &str) -> TrainConfig {
    TrainConfig {
        run_name: format!("bench-{learners}L-{threads}T-{exchange}-{topology}"),
        model_name: "native_mlp".into(),
        n_learners: learners,
        batch_per_learner: BATCH,
        epochs: 1,
        steps_per_epoch: STEPS,
        lr: LrSchedule::Constant(0.05),
        compression: Config {
            lt_override: 50,
            ..Config::with_kind(Kind::AdaComp)
        },
        topology: topology.into(),
        seed: 17,
        threads,
        exchange: exchange.into(),
        ..TrainConfig::default()
    }
}

/// One engine run on the shared MLP workload; returns (wall seconds, final
/// train loss bits, fabric).
fn run_engine_cfg(cfg: &TrainConfig) -> anyhow::Result<(f64, u64, adacomp::comm::FabricStats)> {
    let ds = GaussianMixture::new(7, DIMS[0], *DIMS.last().unwrap(), 4096, 64, 0.5);
    let exe = NativeMlp::new(DIMS, 64);
    let params = exe.init_params(3);
    let layout = exe.layout().clone();
    let mut engine = Engine::new(&exe, &ds, &layout);
    let sw = Stopwatch::start();
    let rec = engine.run(cfg, &params)?;
    let wall = sw.secs();
    Ok((
        wall,
        rec.epochs.last().unwrap().train_loss.to_bits(),
        rec.fabric,
    ))
}

fn run_engine(
    learners: usize,
    threads: usize,
    exchange: &str,
    topology: &str,
) -> anyhow::Result<(f64, u64, adacomp::comm::FabricStats)> {
    run_engine_cfg(&engine_cfg(learners, threads, exchange, topology))
}

/// Isolated hot-path timings for one (layout, compression, learner count):
/// mean pack ns (per learner·step, all layers) and mean steady-state
/// exchange_into ns. Shared by the MLP sweep and the char-LSTM row so both
/// BENCH_engine.json entries measure the same protocol.
fn hot_path(layout: &Layout, learners: usize, comp_cfg: &Config) -> (f64, f64) {
    let lens: Vec<usize> = layout.layer_lens();

    // pack: one compressor over a fixed gradient, recycling its packets
    let mut comp = compress::build(comp_cfg, layout);
    let mut rng = Pcg32::seeded(11);
    let dw = rng.normal_vec(layout.total, 0.1);
    let mut slot: Vec<Packet> = Vec::with_capacity(lens.len());
    let pack_samples = time_n(
        || {
            for spent in slot.drain(..) {
                comp.recycle(spent);
            }
            for li in 0..lens.len() {
                slot.push(comp.pack_layer(li, layout.view(li, &dw)));
            }
        },
        5,
        200,
    );

    // exchange: fixed packets, persistent Reduced (the engine's shape)
    let per_learner: Vec<Vec<Packet>> = (0..learners)
        .map(|l| {
            let mut c = compress::build(
                &Config {
                    seed: l as u64,
                    ..comp_cfg.clone()
                },
                layout,
            );
            let mut rng = Pcg32::seeded(100 + l as u64);
            (0..lens.len())
                .map(|li| c.pack_layer(li, &rng.normal_vec(lens[li], 0.1)))
                .collect()
        })
        .collect();
    let mut topo = topology::build("ring", learners).unwrap();
    let mut fabric = Fabric::new(LinkModel::default());
    let mut reduced = adacomp::comm::Reduced::new(&lens);
    let ex_samples = time_n(
        || {
            topo.exchange_into(&per_learner, &lens, &mut fabric, &mut reduced);
        },
        5,
        200,
    );

    (
        Stats::from(&pack_samples).mean_ns,
        Stats::from(&ex_samples).mean_ns,
    )
}

fn engine_sweep() -> anyhow::Result<()> {
    let auto = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("# engine sweep: NativeMlp {DIMS:?}, batch {BATCH}, {STEPS} steps, adacomp lt=50");
    println!(
        "{:<9} {:>10} {:>12} {:>12} {:>9} {:>12} {:>13} {:>13} {:>9}",
        "learners",
        "seq-wall",
        "par-wall",
        "strm-wall",
        "bit-eq",
        "steps/s",
        "sim-step",
        "sim-barrier",
        "proj-x"
    );

    let mlp_layout = NativeMlp::new(DIMS, 64).layout().clone();
    let mlp_comp = Config {
        lt_override: 50,
        ..Config::with_kind(Kind::AdaComp)
    };
    let mut rows: Vec<Json> = Vec::new();
    for learners in [1usize, 4, 16] {
        let (seq_wall, seq_bits, _) = run_engine(learners, 1, "barrier", "ring")?;
        let (par_wall, par_bits, barrier_fab) = run_engine(learners, 0, "barrier", "ring")?;
        let (strm_wall, strm_bits, strm_fab) = run_engine(learners, 0, "streamed", "ring")?;
        let bit_eq = seq_bits == par_bits && seq_bits == strm_bits;
        let (pack_ns, ex_ns) = hot_path(&mlp_layout, learners, &mlp_comp);
        let steps_per_sec = STEPS as f64 / strm_wall;

        // simulated step times: the streamed run's overlapped placement vs
        // the *same* measured compute behind a barrier (structural win), and
        // the independent barrier run's own placement for cross-checking
        let sim_step = strm_fab.sim_step_s();
        let sim_step_barrier = strm_fab.sim_barrier_s / strm_fab.steps.max(1) as f64;
        let projected = strm_fab.projected_speedup();
        println!(
            "{:<9} {:>9.3}s {:>11.3}s {:>11.3}s {:>9} {:>12.1} {:>12.2}ms {:>12.2}ms {:>8.2}x",
            learners,
            seq_wall,
            par_wall,
            strm_wall,
            bit_eq,
            steps_per_sec,
            1e3 * sim_step,
            1e3 * sim_step_barrier,
            projected
        );
        assert!(
            bit_eq,
            "threads=0/1 and streamed/barrier must all be bit-identical"
        );
        if learners > 1 {
            // the overlap pipeline's simulated step must be strictly below
            // the barrier placement of the very same run (acceptance gate)
            assert!(
                strm_fab.sim_overlap_s < strm_fab.sim_barrier_s,
                "{learners}L: overlap {} !< barrier {}",
                strm_fab.sim_overlap_s,
                strm_fab.sim_barrier_s
            );
        }
        rows.push(json::obj(vec![
            ("learners", json::num(learners as f64)),
            ("threads_auto", json::num(auto as f64)),
            ("scheme", json::s("adacomp")),
            ("seq_wall_secs", json::num(seq_wall)),
            ("par_wall_secs", json::num(par_wall)),
            ("streamed_wall_secs", json::num(strm_wall)),
            ("speedup", json::num(seq_wall / par_wall)),
            ("steps_per_sec", json::num(steps_per_sec)),
            ("pack_ns", json::num(pack_ns)),
            ("exchange_ns", json::num(ex_ns)),
            // streamed pipeline placement (overlapped), barrier placement of
            // the same compute, and the independent barrier run
            ("sim_step_s", json::num(sim_step)),
            ("sim_step_barrier_s", json::num(sim_step_barrier)),
            ("sim_step_barrier_run_s", json::num(barrier_fab.sim_step_s())),
            // overlapped+compressed vs dense/barrier — the paper's rates as
            // wall-clock step-time wins
            ("projected_speedup", json::num(projected)),
            ("bit_identical", Json::Bool(bit_eq)),
            ("worker_pool", Json::Bool(true)),
        ]));
    }

    let doc = json::obj(vec![
        (
            "workload",
            json::obj(vec![
                ("model", json::s("native_mlp")),
                ("dims", json::arr(DIMS.iter().map(|&d| json::num(d as f64)).collect())),
                ("batch_per_learner", json::num(BATCH as f64)),
                ("steps", json::num(STEPS as f64)),
                ("scheme", json::s("adacomp")),
            ]),
        ),
        ("engine", json::arr(rows)),
        ("topology_sweep", topology_sweep()?),
        ("staleness_sweep", staleness_sweep()?),
        ("churn_sweep", churn_sweep()?),
        ("pool", pool_overhead()?),
        ("char_lstm", char_lstm_row()?),
    ]);
    std::fs::write("BENCH_engine.json", doc.to_string())?;
    println!(
        "\nwrote BENCH_engine.json (wall + simulated step times, projected_speedup, topology \
         sweep, staleness sweep, churn sweep, pool constant, char_lstm row)"
    );
    Ok(())
}

/// Bounded-staleness sweep at 16 learners: K ∈ {0, 1, 2} × jitter ∈
/// {0, 0.3} on the streamed ring, same workload. Reports the simulated
/// step time, stall accounting, and projected speedup per row; asserts
/// the window's acceptance gate — under jitter 0.3 the K = 2 schedule's
/// simulated step time is strictly below the synchronous (K = 0) one,
/// because the synchronous fleet pays the max over 16 jitter draws (plus
/// every straggler episode) at every step, while the window lets fast
/// learners run ahead and amortize the stragglers.
fn staleness_sweep() -> anyhow::Result<Json> {
    const LEARNERS: usize = 16;
    println!("\n# staleness sweep ({LEARNERS} learners, ring, streamed, adacomp lt=50)");
    println!(
        "{:<4} {:>7} {:>12} {:>13} {:>14} {:>13} {:>9}",
        "K", "jitter", "steps/s", "sim-step", "stall/l-step", "max-crit", "proj-x"
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut sim: Vec<(usize, f64, f64)> = Vec::new(); // (K, jitter, sim_step_s)
    let mut loss_by_k: Vec<(usize, u64)> = Vec::new();
    for k in [0usize, 1, 2] {
        for jitter in [0.0f64, 0.3] {
            let mut cfg = engine_cfg(LEARNERS, 0, "streamed", "ring");
            cfg.run_name = format!("bench-stale{k}-jit{jitter}");
            cfg.staleness = k;
            cfg.link.jitter = jitter;
            let (wall, bits, fab) = run_engine_cfg(&cfg)?;
            let max_crit = fab
                .crit_share()
                .into_iter()
                .fold(0.0f64, f64::max);
            println!(
                "{:<4} {:>7} {:>12.1} {:>12.3}ms {:>13.3}ms {:>13.2} {:>8.2}x",
                k,
                jitter,
                STEPS as f64 / wall,
                1e3 * fab.sim_step_s(),
                1e3 * fab.stall_per_step_s(),
                max_crit,
                fab.projected_speedup()
            );
            rows.push(json::obj(vec![
                ("staleness", json::num(k as f64)),
                ("jitter", json::num(jitter)),
                ("learners", json::num(LEARNERS as f64)),
                ("steps_per_sec", json::num(STEPS as f64 / wall)),
                ("sim_step_s", json::num(fab.sim_step_s())),
                ("stall_s", json::num(fab.stall_s)),
                ("stall_per_learner_step_s", json::num(fab.stall_per_step_s())),
                ("max_crit_share", json::num(max_crit)),
                ("projected_speedup", json::num(fab.projected_speedup())),
            ]));
            sim.push((k, jitter, fab.sim_step_s()));
            loss_by_k.push((k, bits));
        }
    }
    // determinism: jitter is timeline-only — for a fixed K both jitter
    // settings are bit-identical; K > 0 genuinely delays gradients
    for k in [0usize, 1, 2] {
        let bits: Vec<u64> = loss_by_k
            .iter()
            .filter(|&&(kk, _)| kk == k)
            .map(|&(_, b)| b)
            .collect();
        assert!(bits.windows(2).all(|w| w[0] == w[1]), "K={k} jitter must be timeline-only");
    }
    // acceptance gate: K = 2 strictly beats synchronous on the simulated
    // step time under jitter 0.3. The straggler episodes make the margin
    // wide (~tens of percent of compute), far above run-to-run measurement
    // noise in the per-learner compute spans — if this ever fires
    // spuriously, suspect a machine under extreme load.
    let step_of = |k: usize, j: f64| {
        sim.iter()
            .find(|&&(kk, jj, _)| kk == k && jj == j)
            .map(|&(_, _, s)| s)
            .unwrap()
    };
    assert!(
        step_of(2, 0.3) < step_of(0, 0.3),
        "K=2 sim step {} !< K=0 sim step {} at jitter 0.3",
        step_of(2, 0.3),
        step_of(0, 0.3)
    );
    Ok(json::arr(rows))
}

/// Elastic-fleet churn sweep at 8 learners on the streamed ring: one
/// scripted schedule mixing all three event kinds, plus a matched
/// fail-vs-leave pair losing / handing over the same residual mass.
/// Per-event rows report the recovery cost the membership epoch charged
/// to the simulated timeline (rebuild_s, drain-stall) and the residual
/// mass that was lost (fail) or folded into the survivors (leave).
fn churn_sweep() -> anyhow::Result<Json> {
    const LEARNERS: usize = 8;
    println!("\n# churn sweep ({LEARNERS} learners, ring, streamed, adacomp lt=50)");
    println!(
        "{:<22} {:<6} {:>5} {:>8} {:>12} {:>12} {:>12} {:>12}",
        "schedule", "kind", "step", "n-after", "rebuild", "drain-stall", "lost-L1", "handover-L1"
    );
    let run_churn = |name: &str, churn: &str| -> anyhow::Result<(u64, adacomp::comm::FabricStats)> {
        let mut cfg = engine_cfg(LEARNERS, 0, "streamed", "ring");
        cfg.run_name = format!("bench-churn-{name}");
        cfg.staleness = 2;
        cfg.churn = churn.into();
        let (_, bits, fab) = run_engine_cfg(&cfg)?;
        Ok((bits, fab))
    };
    let mut rows: Vec<Json> = Vec::new();
    let mut emit = |schedule: &str, fab: &adacomp::comm::FabricStats| {
        for m in &fab.membership {
            println!(
                "{:<22} {:<6} {:>5} {:>8} {:>10.3}ms {:>10.3}ms {:>12.4} {:>12.4}",
                schedule,
                m.kind,
                m.step,
                m.n_after,
                1e3 * m.rebuild_s,
                1e3 * m.drain_stall_s,
                m.lost_l1,
                m.handover_l1
            );
            rows.push(json::obj(vec![
                ("schedule", json::s(schedule)),
                ("kind", json::s(&m.kind)),
                ("step", json::num(m.step as f64)),
                ("count", json::num(m.count as f64)),
                ("n_after", json::num(m.n_after as f64)),
                ("topology", json::s(&m.topology)),
                ("degraded", Json::Bool(m.degraded)),
                ("rebuild_s", json::num(m.rebuild_s)),
                ("drain_stall_s", json::num(m.drain_stall_s)),
                ("lost_residual_l1", json::num(m.lost_l1)),
                ("handover_l1", json::num(m.handover_l1)),
            ]));
        }
    };

    // mixed schedule: every event kind exercised in one run
    let mixed = "fail@10:2,join@20:2,leave@30:2";
    let (_, fab) = run_churn("mixed", mixed)?;
    assert_eq!(fab.membership.len(), 3, "mixed schedule must record 3 events");
    emit(mixed, &fab);

    // matched pair: identical prefix, so the residual mass at stake is the
    // same — fail loses it, leave folds it into the survivors
    let (fail_bits, fail) = run_churn("fail", "fail@20:2")?;
    let (leave_bits, leave) = run_churn("leave", "leave@20:2")?;
    emit("fail@20:2", &fail);
    emit("leave@20:2", &leave);
    assert!(fail.lost_residual_l1 > 0.0, "fail must lose residual mass");
    assert!(leave.handover_l1 > 0.0 && leave.lost_residual_l1 == 0.0);
    assert_ne!(fail_bits, leave_bits, "fail and leave must diverge in loss");
    println!(
        "matched pair @20:2 — lost (fail) {:.4} vs handed over (leave) {:.4} L1",
        fail.lost_residual_l1, leave.handover_l1
    );
    Ok(json::arr(rows))
}

/// Reduce-plan topology sweep at 16 learners, streamed: flat ps vs sharded
/// ps:4 vs hierarchical hier:4 vs ring, same workload and plan. Reports the
/// simulated step time and projected speedup per row and asserts the
/// sharded server strictly beats the flat one on the overlap timeline
/// (port pipelining) with compute canceled out — the acceptance gate for
/// the sharded reduce path.
fn topology_sweep() -> anyhow::Result<Json> {
    const LEARNERS: usize = 16;
    println!("\n# topology sweep ({LEARNERS} learners, streamed, adacomp lt=50)");
    println!(
        "{:<8} {:>12} {:>13} {:>13} {:>12} {:>9}",
        "topo", "steps/s", "sim-step", "comm-tail", "bytes-up", "proj-x"
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut tails: Vec<(String, f64)> = Vec::new();
    let mut loss_bits: Vec<u64> = Vec::new();
    for topo in ["ps", "ps:4", "hier:4", "ring"] {
        let (wall, bits, fab) = run_engine(LEARNERS, 0, "streamed", topo)?;
        let tail = fab.comm_tail_s();
        println!(
            "{:<8} {:>12.1} {:>12.3}ms {:>12.3}ms {:>12} {:>8.2}x",
            topo,
            STEPS as f64 / wall,
            1e3 * fab.sim_step_s(),
            1e3 * tail / fab.steps.max(1) as f64,
            fab.bytes_up,
            fab.projected_speedup()
        );
        rows.push(json::obj(vec![
            ("topology", json::s(topo)),
            ("learners", json::num(LEARNERS as f64)),
            ("steps_per_sec", json::num(STEPS as f64 / wall)),
            ("sim_step_s", json::num(fab.sim_step_s())),
            ("comm_tail_s", json::num(tail / fab.steps.max(1) as f64)),
            ("bytes_up", json::num(fab.bytes_up as f64)),
            ("projected_speedup", json::num(fab.projected_speedup())),
        ]));
        tails.push((topo.to_string(), tail));
        loss_bits.push(bits);
    }
    // determinism across topologies (the reduce-plan contract)
    assert!(
        loss_bits.iter().all(|&b| b == loss_bits[0]),
        "all topologies must be bit-identical"
    );
    // acceptance gate: ps:4 strictly beats ps at 16 learners — the sharded
    // ports pipeline bucket rounds the single-port server serializes.
    // (Round costs are simulated and identical across the bit-identical
    // runs; the gate could only tie if scheduler preemption stretched the
    // gap between consecutive bucket completions past a full ~0.9ms round
    // in EVERY one of the 40 steps — if this ever fires spuriously, suspect
    // a machine under extreme load, not the reduce path.)
    let tail_of = |name: &str| {
        tails
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, t)| *t)
            .unwrap()
    };
    assert!(
        tail_of("ps:4") < tail_of("ps"),
        "ps:4 comm tail {} !< ps comm tail {}",
        tail_of("ps:4"),
        tail_of("ps")
    );
    Ok(json::arr(rows))
}

/// The persistent-pool constant-cost win: per-step cost of a pooled engine
/// step on a near-trivial workload (where the per-step constant dominates)
/// next to what the retired per-step `thread::scope` spawn/join costs for
/// the same thread count.
fn pool_overhead() -> anyhow::Result<Json> {
    const TINY_STEPS: usize = 200;
    let threads = 4usize;

    // what the old engine paid every step, measured directly
    let iters = 200usize;
    let sw = Stopwatch::start();
    for _ in 0..iters {
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| {});
            }
        });
    }
    let scoped_spawn_ns = sw.secs() * 1e9 / iters as f64;

    // pooled engine on a tiny model: per-step wall ≈ pool constant + ε
    let ds = GaussianMixture::new(5, 8, 4, 512, 32, 0.5);
    let exe = NativeMlp::new(&[8, 8, 4], 16);
    let params = exe.init_params(2);
    let layout = exe.layout().clone();
    let cfg = TrainConfig {
        run_name: "bench-pool-overhead".into(),
        model_name: "native_mlp".into(),
        n_learners: 4,
        batch_per_learner: 4,
        epochs: 1,
        steps_per_epoch: TINY_STEPS,
        lr: LrSchedule::Constant(0.05),
        compression: Config::with_kind(Kind::None),
        seed: 3,
        threads,
        ..TrainConfig::default()
    };
    let mut engine = Engine::new(&exe, &ds, &layout);
    let sw = Stopwatch::start();
    engine.run(&cfg, &params)?;
    let pool_step_ns = sw.secs() * 1e9 / TINY_STEPS as f64;

    println!(
        "\n# pool constant ({threads} workers): scoped spawn {} / step (retired) vs pooled \
         step {} (tiny model, all-in)",
        fmt_ns(scoped_spawn_ns),
        fmt_ns(pool_step_ns)
    );
    Ok(json::obj(vec![
        ("threads", json::num(threads as f64)),
        ("scoped_spawn_ns_per_step", json::num(scoped_spawn_ns)),
        ("pool_step_ns", json::num(pool_step_ns)),
    ]))
}

/// The paper's recurrent workload on the native layer-graph backend:
/// embed -> LSTM -> fc over Markov-Shakespeare, AdaComp at the fc/lstm/embed
/// L_T default of 500. One row: steps/sec plus isolated pack/exchange ns.
fn char_lstm_row() -> anyhow::Result<Json> {
    use adacomp::data::shakespeare::Shakespeare;
    use adacomp::runtime::native_lstm::NativeCharLstm;

    const LEARNERS: usize = 4;
    const LSTM_BATCH: usize = 8;
    const LSTM_STEPS: usize = 10;
    const SEQ_LEN: usize = 32;

    let ds = Shakespeare::new(17, 60_000, SEQ_LEN, 1024, 64);
    let exe = NativeCharLstm::new(67, 32, &[64], 16)?;
    let params = exe.init_params(3);
    let layout = exe.layout().clone();
    let cfg = TrainConfig {
        run_name: "bench-char-lstm".into(),
        model_name: "char_lstm".into(),
        backend: "native".into(),
        n_learners: LEARNERS,
        batch_per_learner: LSTM_BATCH,
        epochs: 1,
        steps_per_epoch: LSTM_STEPS,
        lr: LrSchedule::Constant(2e-3),
        optimizer: "adam".into(),
        momentum: 0.0,
        compression: Config::with_kind(Kind::AdaComp),
        seed: 29,
        threads: 1,
        ..TrainConfig::default()
    };
    let sw = Stopwatch::start();
    let mut engine = Engine::new(&exe, &ds, &layout);
    let rec = engine.run(&cfg, &params)?;
    let seq_wall = sw.secs();

    let mut par_cfg = cfg.clone();
    par_cfg.threads = 0;
    let sw = Stopwatch::start();
    let mut engine = Engine::new(&exe, &ds, &layout);
    let par_rec = engine.run(&par_cfg, &params)?;
    let par_wall = sw.secs();
    let bit_eq = rec.epochs.last().unwrap().train_loss.to_bits()
        == par_rec.epochs.last().unwrap().train_loss.to_bits();
    assert!(bit_eq, "char-lstm threads=0 and threads=1 must be bit-identical");

    // isolated hot path on the char-lstm layout — same protocol as the MLP
    // sweep, at the fc/lstm/embed L_T default of 500
    let (pack_ns, ex_ns) = hot_path(&layout, LEARNERS, &Config::with_kind(Kind::AdaComp));
    let steps_per_sec = LSTM_STEPS as f64 / par_wall;
    println!(
        "\n# char-lstm ({LEARNERS} learners x batch {LSTM_BATCH}, seq {SEQ_LEN}, adacomp lt=500)"
    );
    println!(
        "seq {seq_wall:.3}s  par {par_wall:.3}s  speedup {:.2}x  {steps_per_sec:.1} steps/s  pack {}  exchange {}",
        seq_wall / par_wall,
        fmt_ns(pack_ns),
        fmt_ns(ex_ns)
    );
    Ok(json::obj(vec![
        ("model", json::s("native_char_lstm")),
        ("learners", json::num(LEARNERS as f64)),
        ("batch_per_learner", json::num(LSTM_BATCH as f64)),
        ("seq_len", json::num(SEQ_LEN as f64)),
        ("steps", json::num(LSTM_STEPS as f64)),
        ("seq_wall_secs", json::num(seq_wall)),
        ("par_wall_secs", json::num(par_wall)),
        ("steps_per_sec", json::num(steps_per_sec)),
        ("pack_ns", json::num(pack_ns)),
        ("exchange_ns", json::num(ex_ns)),
        ("sim_step_s", json::num(par_rec.fabric.sim_step_s())),
        (
            "sim_step_barrier_s",
            json::num(par_rec.fabric.sim_barrier_s / par_rec.fabric.steps.max(1) as f64),
        ),
        ("projected_speedup", json::num(par_rec.fabric.projected_speedup())),
        ("bit_identical", Json::Bool(bit_eq)),
    ]))
}

/// Adaptive-control-plane sweep: 16 learners, jitter 0.3, streamed ring.
/// Hand-tuned static points K ∈ {0, 1, 2} (controller off) vs one
/// controller run that starts synchronous (K = 0, headroom cap 2) and must
/// discover the window itself. Gates:
///
/// * the controller's full-run mean simulated step time strictly beats the
///   worst static point (it pays at most a few epochs of ramp-up),
/// * its steady-state *marginal* step time — the (6-epoch − 3-epoch) run
///   difference, which cancels the shared ramp-up prefix — matches the
///   best static point within a 5% noise band,
/// * it actually re-tuned: the decision timeline is non-empty and the last
///   staleness decision lands on the best static K,
/// * determinism: the run and its decision timeline are bit-identical
///   across thread counts and exchange modes, and the 3-epoch timeline is
///   a prefix of the 6-epoch one (pure function of epoch measurements).
fn controller_sweep() -> anyhow::Result<Json> {
    const LEARNERS: usize = 16;
    const CTRL_STEPS: usize = 20; // per epoch
    const EPOCHS_FULL: usize = 6;
    const EPOCHS_HALF: usize = 3;
    const JITTER: f64 = 0.3;

    let cfg_for = |name: &str,
                   k: usize,
                   controller: &str,
                   epochs: usize,
                   threads: usize,
                   exchange: &str| {
        let mut cfg = engine_cfg(LEARNERS, threads, exchange, "ring");
        cfg.run_name = format!("bench-ctrl-{name}");
        cfg.staleness = k;
        cfg.link.jitter = JITTER;
        cfg.epochs = epochs;
        cfg.steps_per_epoch = CTRL_STEPS;
        cfg.controller = controller.into();
        cfg
    };

    println!(
        "\n# controller sweep ({LEARNERS} learners, jitter {JITTER}, ring, streamed, adacomp lt=50)"
    );
    println!(
        "{:<16} {:>3} {:>13} {:>13} {:>9}",
        "point", "K", "sim-step", "stall/l-step", "retunes"
    );
    let mut rows: Vec<Json> = Vec::new();

    // hand-tuned static grid (controller off)
    let mut static_sim: Vec<(usize, f64)> = Vec::new();
    for k in [0usize, 1, 2] {
        let (_, _, fab) = run_engine_cfg(&cfg_for(&format!("static{k}"), k, "off", EPOCHS_FULL, 0, "streamed"))?;
        assert!(fab.control.is_empty() && fab.control_retunes == 0, "controller off must not re-tune");
        println!(
            "{:<16} {:>3} {:>12.3}ms {:>12.3}ms {:>9}",
            "static", k, 1e3 * fab.sim_step_s(), 1e3 * fab.stall_per_step_s(), 0
        );
        rows.push(json::obj(vec![
            ("mode", json::s("static")),
            ("staleness", json::num(k as f64)),
            ("jitter", json::num(JITTER)),
            ("learners", json::num(LEARNERS as f64)),
            ("sim_step_s", json::num(fab.sim_step_s())),
            ("stall_per_learner_step_s", json::num(fab.stall_per_step_s())),
            ("projected_speedup", json::num(fab.projected_speedup())),
        ]));
        static_sim.push((k, fab.sim_step_s()));
    }
    let best = static_sim.iter().map(|&(_, s)| s).fold(f64::INFINITY, f64::min);
    let worst = static_sim.iter().map(|&(_, s)| s).fold(0.0f64, f64::max);

    // the controller run: starts synchronous, discovers the window
    let (_, ctrl_bits, ctrl) =
        run_engine_cfg(&cfg_for("on", 0, "on", EPOCHS_FULL, 0, "streamed"))?;
    let (_, _, half) = run_engine_cfg(&cfg_for("on-half", 0, "on", EPOCHS_HALF, 0, "streamed"))?;
    let total = ctrl.sim_step_s() * ctrl.steps.max(1) as f64;
    let half_total = half.sim_step_s() * half.steps.max(1) as f64;
    let marginal = (total - half_total) / (ctrl.steps - half.steps).max(1) as f64;
    println!(
        "{:<16} {:>3} {:>12.3}ms {:>12.3}ms {:>9}",
        "controller", "-", 1e3 * ctrl.sim_step_s(), 1e3 * ctrl.stall_per_step_s(),
        ctrl.control_retunes
    );
    println!(
        "controller steady-state marginal {:.3}ms vs best static {:.3}ms (worst {:.3}ms)",
        1e3 * marginal, 1e3 * best, 1e3 * worst
    );
    for d in &ctrl.control {
        println!("  e{} {} {} -> {}  [{}]", d.epoch, d.knob, d.old, d.new, d.signal);
    }

    // gates (see doc comment). The static grid's worst-vs-best margin under
    // jitter 0.3 is tens of percent of compute (straggler episodes), far
    // above measurement noise in the per-learner compute spans.
    assert!(!ctrl.control.is_empty(), "controller must re-tune under jitter 0.3");
    assert!(
        ctrl.sim_step_s() < worst,
        "controller sim step {} !< worst static {}",
        ctrl.sim_step_s(),
        worst
    );
    assert!(
        marginal <= best * 1.05,
        "controller marginal {} !<= best static {} * 1.05",
        marginal,
        best
    );
    let last_k = ctrl
        .control
        .iter()
        .rev()
        .find(|d| d.knob == "staleness")
        .map(|d| d.new);
    // starting synchronous, the headroom cap is staleness_cap(0) = 2 — the
    // straggler signal at jitter 0.3 stays above the widen band, so the
    // window must climb all the way to the cap (== the best static K)
    assert_eq!(
        last_k,
        Some(2.0),
        "controller must widen the staleness window to the cap"
    );
    // determinism: same decisions and same final loss at every thread count
    // and exchange mode; the half run's timeline is a prefix of the full one
    let (_, seq_bits, seq) = run_engine_cfg(&cfg_for("on-seq", 0, "on", EPOCHS_FULL, 1, "streamed"))?;
    let (_, bar_bits, bar) = run_engine_cfg(&cfg_for("on-bar", 0, "on", EPOCHS_FULL, 0, "barrier"))?;
    assert_eq!(ctrl_bits, seq_bits, "controller run must be bit-identical across thread counts");
    assert_eq!(ctrl_bits, bar_bits, "controller run must be bit-identical across exchange modes");
    assert_eq!(ctrl.control, seq.control, "decision timeline must not depend on thread count");
    assert_eq!(ctrl.control, bar.control, "decision timeline must not depend on exchange mode");
    assert_eq!(
        half.control[..],
        ctrl.control[..half.control.len()],
        "the 3-epoch timeline must be a prefix of the 6-epoch one"
    );

    rows.push(json::obj(vec![
        ("mode", json::s("controller")),
        ("staleness_initial", json::num(0.0)),
        ("jitter", json::num(JITTER)),
        ("learners", json::num(LEARNERS as f64)),
        ("epochs", json::num(EPOCHS_FULL as f64)),
        ("sim_step_s", json::num(ctrl.sim_step_s())),
        ("sim_step_marginal_s", json::num(marginal)),
        ("best_static_sim_step_s", json::num(best)),
        ("worst_static_sim_step_s", json::num(worst)),
        ("stall_per_learner_step_s", json::num(ctrl.stall_per_step_s())),
        ("projected_speedup", json::num(ctrl.projected_speedup())),
        ("control_retunes", json::num(ctrl.control_retunes as f64)),
        (
            "decisions",
            json::arr(
                ctrl.control
                    .iter()
                    .map(|d| {
                        json::obj(vec![
                            ("epoch", json::num(d.epoch as f64)),
                            ("knob", json::s(&d.knob)),
                            ("old", json::num(d.old)),
                            ("new", json::num(d.new)),
                            ("signal", json::s(&d.signal)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]));
    Ok(json::arr(rows))
}

/// Run the controller sweep and write its own machine-readable file (the
/// CI gate checks for it in both `--fast` and full runs).
fn controller_bench() -> anyhow::Result<()> {
    let doc = json::obj(vec![("controller_sweep", controller_sweep()?)]);
    std::fs::write("BENCH_controller.json", doc.to_string())?;
    println!("\nwrote BENCH_controller.json (static grid vs adaptive controller, decision timeline)");
    Ok(())
}

#[cfg(feature = "pjrt")]
fn pjrt_breakdown() -> anyhow::Result<()> {
    use adacomp::harness::{dataset_for, defaults_for};
    use adacomp::models::Manifest;
    use adacomp::runtime::pjrt::PjrtExecutor;
    use adacomp::runtime::{Batch, Executor};

    let dir = adacomp::harness::default_artifacts_dir();
    let manifest = match Manifest::load(dir) {
        Ok(m) => m,
        Err(_) => {
            println!("artifacts missing — run `make artifacts` first; skipping PJRT breakdown");
            return Ok(());
        }
    };

    println!(
        "\n{:<12} {:>9} {:>6} {:>12} {:>12} {:>12} {:>12} {:>10}",
        "model", "params", "batch", "step(hlo)", "pack", "exchange", "update", "pack-%"
    );
    for model in ["mnist_dnn", "cifar_cnn", "bn50_dnn_s", "char_lstm", "transformer"] {
        if manifest.model(model).is_err() {
            continue;
        }
        let meta = manifest.model(model)?.clone();
        let params = manifest.load_init(&meta)?;
        let mut exe = PjrtExecutor::new(&manifest, model)?;
        let d = defaults_for(model);
        let ds = dataset_for(model, 1, 512.max(d.batch * 2), 128, meta.seq_len)?;
        let bs = meta.batch;
        let mut batch = if ds.int_input() {
            Batch::i32(vec![0; bs * ds.x_elems()], vec![0; bs * ds.y_elems()], bs)
        } else {
            Batch::f32(vec![0.0; bs * ds.x_elems()], vec![0; bs * ds.y_elems()], bs)
        };
        let idx: Vec<usize> = (0..bs).collect();
        if batch.x_i32.is_empty() {
            ds.fill(adacomp::data::Split::Train, &idx, adacomp::data::XBuf::F32(&mut batch.x_f32), &mut batch.y);
        } else {
            ds.fill(adacomp::data::Split::Train, &idx, adacomp::data::XBuf::I32(&mut batch.x_i32), &mut batch.y);
        }

        let cfg = Config::with_kind(Kind::AdaComp);
        let mut comp = compress::build(&cfg, &meta.layout);
        let mut topo = topology::build("ring", 2).unwrap();
        let mut fabric = Fabric::new(LinkModel::default());
        let lens: Vec<usize> = meta.layout.layer_lens();
        let mut opt = adacomp::optim::Sgd::new(params.len(), 0.9);
        let mut p = params.clone();

        let iters = 8usize;
        let (mut t_step, mut t_pack, mut t_ex, mut t_up) =
            (Vec::new(), Vec::new(), Vec::new(), Vec::new());
        // warmup (compile)
        let _ = exe.step(&p, &batch)?;
        for _ in 0..iters {
            let sw = Stopwatch::start();
            let out = exe.step(&p, &batch)?;
            t_step.push((sw.secs() * 1e9) as u64);

            let sw = Stopwatch::start();
            let packets: Vec<Packet> = (0..meta.layout.num_layers())
                .map(|li| comp.pack_layer(li, meta.layout.view(li, &out.grads)))
                .collect();
            t_pack.push((sw.secs() * 1e9) as u64);

            let sw = Stopwatch::start();
            let per_learner = vec![packets; 2];
            let red = topo.exchange(&per_learner, &lens, &mut fabric);
            t_ex.push((sw.secs() * 1e9) as u64);

            let sw = Stopwatch::start();
            let mut g = vec![0.0f32; p.len()];
            for (li, s) in red.sums.iter().enumerate() {
                meta.layout.view_mut(li, &mut g).copy_from_slice(s);
            }
            use adacomp::optim::Optimizer;
            opt.step(&mut p, &g, 0.01);
            t_up.push((sw.secs() * 1e9) as u64);
        }
        let (ss, sp, se, su) = (
            Stats::from(&t_step),
            Stats::from(&t_pack),
            Stats::from(&t_ex),
            Stats::from(&t_up),
        );
        println!(
            "{:<12} {:>9} {:>6} {:>12} {:>12} {:>12} {:>12} {:>9.1}%",
            model,
            meta.layout.total,
            bs,
            fmt_ns(ss.mean_ns),
            fmt_ns(sp.mean_ns),
            fmt_ns(se.mean_ns),
            fmt_ns(su.mean_ns),
            100.0 * sp.mean_ns / ss.mean_ns
        );
    }
    println!("\npack-% = compression cost relative to fwd/bwd — the paper requires this to be small");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    // --fast: only the controller gate (CI's bench job), skipping the full
    // engine sweep
    let fast = std::env::args().any(|a| a == "--fast");
    controller_bench()?;
    if fast {
        return Ok(());
    }
    engine_sweep()?;
    #[cfg(feature = "pjrt")]
    pjrt_breakdown()?;
    Ok(())
}
