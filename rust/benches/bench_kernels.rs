//! Compute-kernel micro-benchmarks: the packed GEMM vs the retired naive
//! matmul, and the vectorized AdaComp bin kernels vs their scalar mirrors.
//!
//! GEMM rows cover the model shapes the native executor actually runs
//! (mnist_dnn fc layers, cifar_cnn im2col panels, char_lstm gate/head
//! matmuls). For each row we time:
//!
//! - `packed` — `tensor::gemm::matmul` as dispatched (AVX2+FMA when the CPU
//!   has it and `ADACOMP_NO_SIMD` is unset),
//! - `scalar` — the same packed kernel with the scalar microkernel forced
//!   (the bit-identical portability lane; `f32::mul_add` per lane),
//! - `naive` — a local copy of the retired pre-packing ikj loops (with
//!   their data-dependent `if av == 0.0` skip), kept here as baseline only.
//!
//! A second sweep times the same packed GEMM at kernel-thread budgets of
//! 1 vs 4 (`gemm_with_threads` over the shared compute pool) on every
//! model shape above the parallel gate, asserting bit-identical outputs
//! always and a strict 4-thread speedup at the large shapes when the
//! machine has >= 4 hardware threads.
//!
//! When the SIMD path is live, every model-shape row asserts the packed
//! kernel strictly beats the retired naive loops, and the SIMD AdaComp
//! pass-1b/pass-2 kernels strictly beat their scalar mirrors — the
//! regression gate the CI smoke enforces by running this bench. Results
//! land in `BENCH_kernels.json`.
//!
//!   cargo bench --bench bench_kernels [-- --fast]

use adacomp::compress::select;
use adacomp::tensor::gemm::{self, GemmScratch};
use adacomp::util::json::{self, Json};
use adacomp::util::rng::Pcg32;
use adacomp::util::timer::{fmt_ns, time_n, Stats};

/// The retired naive ikj matmul (what `tensor::ops` shipped before the
/// packed kernel) — benchmark baseline only, not a production path.
fn naive_matmul(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    c.fill(0.0);
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for (cj, &bj) in crow.iter_mut().zip(brow.iter()) {
                *cj += av * bj;
            }
        }
    }
}

fn gemm_row(model: &str, op: &str, m: usize, k: usize, n: usize, iters: usize) -> Json {
    let mut rng = Pcg32::seeded(1 + (m * 31 + k * 7 + n) as u64);
    let a = rng.normal_vec(m * k, 1.0);
    let b = rng.normal_vec(k * n, 1.0);
    let mut s = GemmScratch::default();

    let mut c_packed = vec![0.0f32; m * n];
    let packed = Stats::from(&time_n(
        || {
            gemm::matmul(&mut s, &a, &b, &mut c_packed, m, k, n, false);
            std::hint::black_box(c_packed[0]);
        },
        2,
        iters,
    ));
    let mut c_scalar = vec![0.0f32; m * n];
    let scalar = Stats::from(&time_n(
        || {
            gemm::gemm_with(true, &mut s, &a, k, 1, &b, n, 1, &mut c_scalar, m, k, n, false);
            std::hint::black_box(c_scalar[0]);
        },
        2,
        iters,
    ));
    let mut c_naive = vec![0.0f32; m * n];
    let naive = Stats::from(&time_n(
        || {
            naive_matmul(&a, &b, &mut c_naive, m, k, n);
            std::hint::black_box(c_naive[0]);
        },
        2,
        iters,
    ));

    // correctness on the benched buffers: packed == forced-scalar bitwise,
    // and both agree with the naive loops numerically
    assert_eq!(
        c_packed.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        c_scalar.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "{model}/{op}: dispatch and forced-scalar GEMM must be bit-identical"
    );
    for (i, (p, nv)) in c_packed.iter().zip(c_naive.iter()).enumerate() {
        assert!(
            (p - nv).abs() <= 1e-3 * nv.abs().max(1.0),
            "{model}/{op}[{i}]: packed {p} vs naive {nv}"
        );
    }

    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    let gflops = |st: &Stats| st.throughput(flops) / 1e9;
    let speedup = naive.median_ns / packed.median_ns;
    if gemm::simd_enabled() {
        assert!(
            packed.median_ns < naive.median_ns,
            "{model}/{op} ({m}x{k}x{n}): packed {} must beat retired naive {}",
            fmt_ns(packed.median_ns),
            fmt_ns(naive.median_ns)
        );
    }
    println!(
        "{:<10} {:<6} {:>5}x{:>4}x{:>4} {:>10} {:>8.2} {:>8.2} {:>8.2} {:>7.2}x",
        model,
        op,
        m,
        k,
        n,
        fmt_ns(packed.median_ns),
        gflops(&packed),
        gflops(&scalar),
        gflops(&naive),
        speedup
    );
    json::obj(vec![
        ("model", json::s(model)),
        ("op", json::s(op)),
        ("m", json::num(m as f64)),
        ("k", json::num(k as f64)),
        ("n", json::num(n as f64)),
        ("packed_gflops", json::num(gflops(&packed))),
        ("scalar_gflops", json::num(gflops(&scalar))),
        ("naive_gflops", json::num(gflops(&naive))),
        ("speedup_vs_naive", json::num(speedup)),
    ])
}

/// Kernel-threads sweep: the same packed GEMM at an explicit budget of 1 vs
/// 4 over the shared compute pool, outputs asserted bit-identical. The
/// strict speedup gate fires only where it can physically hold: >= 4
/// hardware threads and a shape big enough (>= 10 MFlop) that the fork-join
/// handoff is noise against the tile work.
fn par_row(model: &str, op: &str, m: usize, k: usize, n: usize, iters: usize, cores: usize) -> Json {
    let mut rng = Pcg32::seeded(5 + (m * 17 + k * 3 + n) as u64);
    let a = rng.normal_vec(m * k, 1.0);
    let b = rng.normal_vec(k * n, 1.0);
    let mut s = GemmScratch::default();

    let mut c1 = vec![0.0f32; m * n];
    let t1 = Stats::from(&time_n(
        || {
            gemm::gemm_with_threads(false, 1, &mut s, &a, k, 1, &b, n, 1, &mut c1, m, k, n, false);
            std::hint::black_box(c1[0]);
        },
        2,
        iters,
    ));
    let mut c4 = vec![0.0f32; m * n];
    let t4 = Stats::from(&time_n(
        || {
            gemm::gemm_with_threads(false, 4, &mut s, &a, k, 1, &b, n, 1, &mut c4, m, k, n, false);
            std::hint::black_box(c4[0]);
        },
        2,
        iters,
    ));

    // determinism contract on the benched buffers: any budget, same bits
    assert_eq!(
        c1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        c4.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "{model}/{op}: 1-thread and 4-thread GEMM must be bit-identical"
    );

    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    let speedup = t1.median_ns / t4.median_ns;
    let gated = cores >= 4 && flops >= 10e6;
    if gated {
        assert!(
            t4.median_ns < t1.median_ns,
            "{model}/{op} ({m}x{k}x{n}): 4 kernel threads {} must beat 1 {}",
            fmt_ns(t4.median_ns),
            fmt_ns(t1.median_ns)
        );
    }
    let gflops = |st: &Stats| st.throughput(flops) / 1e9;
    println!(
        "{:<10} {:<6} {:>5}x{:>4}x{:>4} 1T {:>10} 4T {:>10} {:>5.2}x{}",
        model,
        op,
        m,
        k,
        n,
        fmt_ns(t1.median_ns),
        fmt_ns(t4.median_ns),
        speedup,
        if gated { "  [gated]" } else { "" }
    );
    json::obj(vec![
        ("model", json::s(model)),
        ("op", json::s(op)),
        ("m", json::num(m as f64)),
        ("k", json::num(k as f64)),
        ("n", json::num(n as f64)),
        ("threads1_gflops", json::num(gflops(&t1))),
        ("threads4_gflops", json::num(gflops(&t4))),
        ("speedup_4_vs_1", json::num(speedup)),
        ("asserted", Json::Bool(gated)),
    ])
}

/// One AdaComp layer's pass-1b + pass-2 over warm residues: SIMD dispatch vs
/// the forced-scalar mirror, outputs asserted bit-identical.
fn pack_pass(
    work: &mut [f32],
    dw: &[f32],
    lt: usize,
    scalar: bool,
    idx: &mut Vec<u32>,
    val: &mut Vec<f32>,
) {
    idx.clear();
    val.clear();
    for (b, (rb, db)) in work.chunks_mut(lt).zip(dw.chunks(lt)).enumerate() {
        let gm = if scalar {
            select::bin_absmax_scalar(rb)
        } else {
            select::bin_absmax(rb)
        };
        if gm <= 0.0 {
            continue;
        }
        let base = (b * lt) as u32;
        if scalar {
            select::select_bin_scalar_into(rb, db, gm, gm, 1.0, base, idx, val);
        } else {
            select::select_bin_into(rb, db, gm, gm, 1.0, base, idx, val);
        }
    }
}

fn pack_row(n: usize, lt: usize, iters: usize) -> Json {
    let mut rng = Pcg32::seeded(7);
    let r0 = rng.normal_vec(n, 1.0);
    let dw = rng.normal_vec(n, 0.5);
    let mut work = r0.clone();
    let (mut idx, mut val) = (Vec::new(), Vec::new());

    let simd = Stats::from(&time_n(
        || {
            work.copy_from_slice(&r0);
            pack_pass(&mut work, &dw, lt, false, &mut idx, &mut val);
            std::hint::black_box(idx.len());
        },
        2,
        iters,
    ));
    let work_simd = work.clone();
    let (idx_simd, val_simd) = (idx.clone(), val.clone());

    let scalar = Stats::from(&time_n(
        || {
            work.copy_from_slice(&r0);
            pack_pass(&mut work, &dw, lt, true, &mut idx, &mut val);
            std::hint::black_box(idx.len());
        },
        2,
        iters,
    ));
    assert_eq!(idx_simd, idx, "pack select: SIMD and scalar indices must match");
    assert_eq!(
        val_simd.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        val.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "pack select: SIMD and scalar values must be bit-identical"
    );
    assert_eq!(
        work_simd.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        work.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "pack select: SIMD and scalar residue updates must be bit-identical"
    );

    let ns_elem = |st: &Stats| st.median_ns / n as f64;
    let speedup = scalar.median_ns / simd.median_ns;
    if select::simd_enabled() {
        assert!(
            simd.median_ns < scalar.median_ns,
            "pack (n={n}, L_T={lt}): SIMD {} must beat scalar {}",
            fmt_ns(simd.median_ns),
            fmt_ns(scalar.median_ns)
        );
    }
    println!(
        "pack n={:<9} L_T={:<5} simd {:>7.3} ns/elem  scalar {:>7.3} ns/elem  {:>5.2}x  sent {}",
        n,
        lt,
        ns_elem(&simd),
        ns_elem(&scalar),
        speedup,
        idx.len()
    );
    json::obj(vec![
        ("n", json::num(n as f64)),
        ("lt", json::num(lt as f64)),
        ("sent", json::num(idx.len() as f64)),
        ("simd_ns_per_elem", json::num(ns_elem(&simd))),
        ("scalar_ns_per_elem", json::num(ns_elem(&scalar))),
        ("speedup", json::num(speedup)),
    ])
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let simd = gemm::simd_enabled();

    println!(
        "# packed GEMM vs retired naive loops (simd={simd}, select_simd={})",
        select::simd_enabled()
    );
    println!(
        "{:<10} {:<6} {:>15} {:>10} {:>8} {:>8} {:>8} {:>8}",
        "model", "op", "m x k x n", "packed", "GF/s", "scal", "naive", "vs naive"
    );
    // the GEMM shapes the native models actually run (batch 32 dense / 8 conv)
    let rows: &[(&str, &str, usize, usize, usize)] = &[
        ("mnist_dnn", "fc1", 32, 784, 300),
        ("mnist_dnn", "fc2", 32, 300, 100),
        ("mnist_dnn", "fc3", 32, 100, 10),
        ("cifar_cnn", "conv1", 8 * 32 * 32, 75, 32),
        ("cifar_cnn", "conv2", 8 * 16 * 16, 800, 32),
        ("cifar_cnn", "conv3", 8 * 8 * 8, 800, 64),
        ("char_lstm", "x@wx", 32, 32, 256),
        ("char_lstm", "h@wh", 32, 64, 256),
        ("char_lstm", "head", 512, 64, 67),
    ];
    let mut gemm_rows = Vec::new();
    for &(model, op, m, k, n) in rows {
        let work = m * k * n;
        let iters = if fast {
            3
        } else if work > 10_000_000 {
            10
        } else {
            40
        };
        gemm_rows.push(gemm_row(model, op, m, k, n, iters));
    }

    // kernel-threads sweep over the model shapes that cross the parallel
    // gate (2mkn >= MIN_PAR_FLOPS); the strict 4-vs-1 speedup assertion
    // fires at the large shapes when the machine has >= 4 hardware threads
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    println!(
        "\n# parallel packed GEMM: kernel-threads 1 vs 4 over the compute pool \
         (cores={cores})"
    );
    let mut par_rows = Vec::new();
    for &(model, op, m, k, n) in rows {
        if 2 * (m as u64) * (k as u64) * (n as u64) < gemm::MIN_PAR_FLOPS {
            continue; // below the gate the kernel stays serial by design
        }
        let work = m * k * n;
        let iters = if fast {
            3
        } else if work > 10_000_000 {
            10
        } else {
            40
        };
        par_rows.push(par_row(model, op, m, k, n, iters, cores));
    }

    println!("\n# adacomp bin kernels: SIMD dispatch vs scalar mirror");
    let pack_shapes: &[(usize, usize)] = if fast {
        &[(25_600, 50)]
    } else {
        &[(25_600, 50), (1_048_576, 50), (1_048_576, 500)]
    };
    let mut pack_rows = Vec::new();
    for &(n, lt) in pack_shapes {
        let iters = if fast {
            5
        } else if n > 500_000 {
            20
        } else {
            100
        };
        pack_rows.push(pack_row(n, lt, iters));
    }

    let doc = json::obj(vec![
        ("simd_enabled", Json::Bool(simd)),
        ("select_simd_enabled", Json::Bool(select::simd_enabled())),
        ("cores", json::num(cores as f64)),
        ("gemm", json::arr(gemm_rows)),
        ("gemm_parallel", json::arr(par_rows)),
        ("pack", json::arr(pack_rows)),
    ]);
    std::fs::write("BENCH_kernels.json", doc.to_string())?;
    println!(
        "\nwrote BENCH_kernels.json (packed-vs-naive GEMM per model shape, \
         kernel-threads 1-vs-4 sweep, SIMD-vs-scalar adacomp bin kernels)"
    );
    Ok(())
}
