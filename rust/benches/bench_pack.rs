//! Pack/unpack micro-benchmarks: the L3 hot path.
//!
//! For every compression scheme, measures pack throughput (elements/s and
//! GB/s of gradient processed) across layer sizes and L_T values, plus the
//! wire encode/decode cost for AdaComp packets. This regenerates the
//! numbers in EXPERIMENTS.md §Perf.
//!
//!   cargo bench --bench bench_pack

use adacomp::compress::{self, wire, Config, Kind};
use adacomp::models::{LayerKind, Layout};
use adacomp::util::rng::Pcg32;
use adacomp::util::timer::{fmt_ns, time_n, Stats};

fn bench_scheme(kind: Kind, n: usize, lt: usize, iters: usize) -> (Stats, usize) {
    let layout = Layout::from_specs(&[("w", &[n], LayerKind::Conv)]);
    let cfg = Config {
        lt_override: lt,
        ..Config::with_kind(kind)
    };
    let mut c = compress::build(&cfg, &layout);
    let mut rng = Pcg32::seeded(42);
    let dw = rng.normal_vec(n, 0.1);
    // steady state: warm the residues so selection counts are realistic
    let mut sent = 0usize;
    let samples = time_n(
        || {
            let p = c.pack_layer(0, &dw);
            sent = p.sent();
            std::hint::black_box(&p);
        },
        3,
        iters,
    );
    (Stats::from(&samples), sent)
}

fn main() {
    println!("# pack() throughput (per layer call, steady-state residues)");
    println!(
        "{:<10} {:>9} {:>6} {:>12} {:>12} {:>10} {:>8}",
        "scheme", "n", "L_T", "mean", "p95", "Melem/s", "GB/s"
    );
    for kind in [
        Kind::AdaComp,
        Kind::LocalSelect,
        Kind::Dryden,
        Kind::OneBit,
        Kind::TernGrad,
        Kind::Strom,
        Kind::None,
    ] {
        for (n, lt) in [(25_600usize, 50usize), (1_048_576, 50), (1_048_576, 500)] {
            let iters = if n > 500_000 { 30 } else { 200 };
            let (s, _sent) = bench_scheme(kind, n, lt, iters);
            let melems = s.throughput(n as f64) / 1e6;
            let gbs = s.throughput(n as f64 * 4.0) / 1e9;
            println!(
                "{:<10} {:>9} {:>6} {:>12} {:>12} {:>10.1} {:>8.2}",
                kind.name(),
                n,
                lt,
                fmt_ns(s.mean_ns),
                fmt_ns(s.p95_ns),
                melems,
                gbs
            );
        }
    }

    println!("\n# adacomp wire encode+decode");
    println!(
        "{:<12} {:>9} {:>6} {:>12} {:>12} {:>10}",
        "op", "n", "L_T", "mean", "p95", "GB/s"
    );
    for (n, lt) in [(25_600usize, 50usize), (1_048_576, 500)] {
        let layout = Layout::from_specs(&[("w", &[n], LayerKind::Conv)]);
        let cfg = Config {
            lt_override: lt,
            ..Config::with_kind(Kind::AdaComp)
        };
        let mut c = compress::build(&cfg, &layout);
        let mut rng = Pcg32::seeded(7);
        let dw = rng.normal_vec(n, 0.1);
        let p = c.pack_layer(0, &dw);
        let scale = p.val.iter().find(|v| **v != 0.0).map(|v| v.abs()).unwrap_or(1.0);

        let iters = if n > 500_000 { 50 } else { 300 };
        let enc = time_n(
            || {
                std::hint::black_box(wire::encode_adacomp(0, n, lt, scale, &p.idx, &p.val));
            },
            3,
            iters,
        );
        let s = Stats::from(&enc);
        println!(
            "{:<12} {:>9} {:>6} {:>12} {:>12} {:>10.2}",
            "encode",
            n,
            lt,
            fmt_ns(s.mean_ns),
            fmt_ns(s.p95_ns),
            s.throughput(n as f64 * 4.0) / 1e9
        );
        let bytes = wire::encode_adacomp(0, n, lt, scale, &p.idx, &p.val);
        let dec = time_n(
            || {
                std::hint::black_box(wire::decode(&bytes).unwrap());
            },
            3,
            iters,
        );
        let s = Stats::from(&dec);
        println!(
            "{:<12} {:>9} {:>6} {:>12} {:>12} {:>10.2}",
            "decode",
            n,
            lt,
            fmt_ns(s.mean_ns),
            fmt_ns(s.p95_ns),
            s.throughput(n as f64 * 4.0) / 1e9
        );
    }

    println!("\n# ablation: soft-threshold scale factor (paper studied 1.5-3.0)");
    println!("{:<8} {:>12} {:>14}", "factor", "mean", "sent/bin");
    for factor in [1.5f32, 2.0, 2.5, 3.0] {
        let n = 1_048_576;
        let layout = Layout::from_specs(&[("w", &[n], LayerKind::Conv)]);
        let cfg = Config {
            lt_override: 50,
            scale_factor: factor,
            ..Config::with_kind(Kind::AdaComp)
        };
        let mut c = compress::build(&cfg, &layout);
        let mut rng = Pcg32::seeded(9);
        let dw = rng.normal_vec(n, 0.1);
        let mut sent = 0usize;
        let samples = time_n(
            || {
                sent = c.pack_layer(0, &dw).sent();
            },
            2,
            20,
        );
        let s = Stats::from(&samples);
        println!(
            "{:<8} {:>12} {:>14.2}",
            factor,
            fmt_ns(s.mean_ns),
            sent as f64 / (n / 50) as f64
        );
    }
}
