//! Pack/unpack and wire-encoding micro-benchmarks: the L3 hot path.
//!
//! For every compression scheme, measures pack throughput (elements/s and
//! GB/s of gradient processed) across layer sizes and L_T values, plus the
//! real wire encode/decode cost per scheme (the `encode_packet_into` /
//! `decode_into` pass the exchange path now runs), the delta-vbyte SIMD
//! kernel against its scalar fallback, and measured-vs-analytic wire bytes.
//! Machine-readable results land in `BENCH_wire.json`:
//!
//! - `schemes`: per-scheme encode/decode throughput + measured vs analytic
//!   bytes for a representative packet,
//! - `vbyte`: the index codec's SIMD-vs-scalar encode/decode throughput
//!   (streams asserted bit-identical),
//! - `adacomp_v2_16bit`: v1 vs v2 bytes at (n=1M, L_T=500) — the 16-bit
//!   slot regime, where the delta-vbyte stream must strictly shrink,
//! - `models`: whole-model adacomp bucket frames for mnist_dnn and
//!   char_lstm, asserting measured <= analytic (the CI smoke's contract).
//!
//! This regenerates the numbers in EXPERIMENTS.md §Perf.
//!
//!   cargo bench --bench bench_pack [-- --fast]

use adacomp::compress::{self, vbyte, wire, Config, Kind, Packet};
use adacomp::harness;
use adacomp::models::{LayerKind, Layout};
use adacomp::util::json::{self, Json};
use adacomp::util::rng::Pcg32;
use adacomp::util::timer::{fmt_ns, time_n, Stats};

fn bench_scheme(kind: Kind, n: usize, lt: usize, iters: usize) -> (Stats, usize) {
    let layout = Layout::from_specs(&[("w", &[n], LayerKind::Conv)]);
    let cfg = Config {
        lt_override: lt,
        ..Config::with_kind(kind)
    };
    let mut c = compress::build(&cfg, &layout);
    let mut rng = Pcg32::seeded(42);
    let dw = rng.normal_vec(n, 0.1);
    // steady state: warm the residues so selection counts are realistic
    let mut sent = 0usize;
    let samples = time_n(
        || {
            let p = c.pack_layer(0, &dw);
            sent = p.sent();
            std::hint::black_box(&p);
        },
        3,
        iters,
    );
    (Stats::from(&samples), sent)
}

/// Steady-state packet for one (scheme, n, lt) — packs a few rounds so the
/// residues are warm, then returns the final packet.
fn steady_packet(kind: Kind, n: usize, lt: usize, seed: u64) -> Packet {
    let layout = Layout::from_specs(&[("w", &[n], LayerKind::Conv)]);
    let cfg = Config {
        lt_override: lt,
        ..Config::with_kind(kind)
    };
    let mut c = compress::build(&cfg, &layout);
    let mut rng = Pcg32::seeded(seed);
    let dw = rng.normal_vec(n, 0.1);
    let mut p = c.pack_layer(0, &dw);
    for _ in 0..2 {
        c.recycle(p);
        p = c.pack_layer(0, &dw);
    }
    p
}

/// Real wire encode + decode timings for one scheme's steady-state packet;
/// prints one table row and returns the BENCH_wire.json entry.
fn wire_scheme_row(kind: Kind, n: usize, lt: usize, iters: usize) -> Json {
    let p = steady_packet(kind, n, lt, 42);
    let analytic = p.wire_bytes;
    let mut buf = Vec::new();
    let enc = time_n(
        || {
            buf.clear();
            wire::encode_packet_into(&p, &mut buf).unwrap();
            std::hint::black_box(buf.len());
        },
        3,
        iters,
    );
    let measured = buf.len();
    let (mut idx, mut val) = (Vec::new(), Vec::new());
    let dec = time_n(
        || {
            wire::decode_into(&buf, &mut idx, &mut val).unwrap();
            std::hint::black_box(idx.len());
        },
        3,
        iters,
    );
    // roundtrip sanity on the benched buffers
    assert_eq!(idx, p.idx, "{} wire roundtrip", kind.name());
    assert_eq!(val.len(), p.val.len());
    let es = Stats::from(&enc);
    let ds = Stats::from(&dec);
    let enc_gbs = es.throughput(n as f64 * 4.0) / 1e9;
    let dec_gbs = ds.throughput(n as f64 * 4.0) / 1e9;
    println!(
        "{:<10} {:>9} {:>6} {:>9} {:>10} {:>10} {:>9.2} {:>9.2}",
        kind.name(),
        n,
        lt,
        p.sent(),
        measured,
        analytic,
        enc_gbs,
        dec_gbs
    );
    json::obj(vec![
        ("scheme", json::s(kind.name())),
        ("n", json::num(n as f64)),
        ("lt", json::num(lt as f64)),
        ("sent", json::num(p.sent() as f64)),
        ("measured_bytes", json::num(measured as f64)),
        ("analytic_bytes", json::num(analytic as f64)),
        ("enc_melems_s", json::num(es.throughput(n as f64) / 1e6)),
        ("dec_melems_s", json::num(ds.throughput(n as f64) / 1e6)),
        ("enc_gbs", json::num(enc_gbs)),
        ("dec_gbs", json::num(dec_gbs)),
    ])
}

/// The index codec alone: SIMD dispatch vs forced-scalar encode/decode on
/// the same stream, streams asserted bit-identical.
fn vbyte_micro(count: usize, iters: usize) -> Json {
    let mut rng = Pcg32::seeded(5);
    let mut idx = Vec::with_capacity(count);
    let mut cur = 0u32;
    for _ in 0..count {
        cur += 1 + rng.below(300); // mixed 1- and 2-byte deltas
        idx.push(cur);
    }
    let mut fast = Vec::new();
    let mut slow = Vec::new();
    let e_f = time_n(
        || {
            fast.clear();
            vbyte::encode_into(&idx, &mut fast);
        },
        3,
        iters,
    );
    let e_s = time_n(
        || {
            slow.clear();
            vbyte::encode_scalar_into(&idx, &mut slow);
        },
        3,
        iters,
    );
    assert_eq!(fast, slow, "SIMD and scalar vbyte streams must be bit-identical");
    let mut out = Vec::new();
    let d_f = time_n(
        || {
            out.clear();
            vbyte::decode_into(count, &fast, &mut out).unwrap();
        },
        3,
        iters,
    );
    assert_eq!(out, idx);
    let d_s = time_n(
        || {
            out.clear();
            vbyte::decode_scalar_into(count, &fast, &mut out).unwrap();
        },
        3,
        iters,
    );
    assert_eq!(out, idx);
    let melems = |s: &Stats| s.throughput(count as f64) / 1e6;
    let (ef, es, df, ds) = (
        Stats::from(&e_f),
        Stats::from(&e_s),
        Stats::from(&d_f),
        Stats::from(&d_s),
    );
    println!(
        "vbyte count {} simd={}: encode {:.0} vs scalar {:.0} Melem/s, decode {:.0} vs {:.0}",
        count,
        vbyte::simd_enabled(),
        melems(&ef),
        melems(&es),
        melems(&df),
        melems(&ds)
    );
    json::obj(vec![
        ("count", json::num(count as f64)),
        ("bytes", json::num(fast.len() as f64)),
        ("simd_enabled", Json::Bool(vbyte::simd_enabled())),
        ("enc_melems_s", json::num(melems(&ef))),
        ("enc_scalar_melems_s", json::num(melems(&es))),
        ("dec_melems_s", json::num(melems(&df))),
        ("dec_scalar_melems_s", json::num(melems(&ds))),
    ])
}

/// v1 vs v2 adacomp bytes in the 16-bit slot regime — the delta-vbyte
/// index stream must strictly shrink the packet here (acceptance gate).
fn adacomp_v2_16bit_row() -> Json {
    let (n, lt) = (1_048_576usize, 500usize);
    let p = steady_packet(Kind::AdaComp, n, lt, 42);
    let scale = p.val.iter().find(|v| **v != 0.0).map(|v| v.abs()).unwrap_or(1.0);
    let v1 = wire::encode_adacomp(0, n, lt, scale, &p.idx, &p.val).unwrap().len();
    assert_eq!(v1, p.wire_bytes, "analytic v1 bytes match the v1 encoder");
    let v2 = wire::encode_packet(&p).unwrap().len();
    assert!(
        v2 < v1,
        "v2 delta-vbyte ({v2}) must strictly shrink v1 ({v1}) at L_T={lt}"
    );
    println!(
        "adacomp 16-bit regime (n={n}, L_T={lt}, sent={}): v1 {v1} B -> v2 {v2} B ({:.2}x)",
        p.sent(),
        v1 as f64 / v2 as f64
    );
    json::obj(vec![
        ("n", json::num(n as f64)),
        ("lt", json::num(lt as f64)),
        ("sent", json::num(p.sent() as f64)),
        ("v1_bytes", json::num(v1 as f64)),
        ("v2_bytes", json::num(v2 as f64)),
    ])
}

/// Whole-model adacomp bucket frame for one registered model: measured
/// frame bytes vs the analytic per-layer accounting. The CI smoke asserts
/// `measured_bytes <= analytic_bytes` from the JSON this returns.
fn model_row(model: &str, steps: usize) -> anyhow::Result<Json> {
    let spec = harness::native_spec(model, 11, 16)?;
    let layout = &spec.layout;
    let mut c = compress::build(&Config::with_kind(Kind::AdaComp), layout);
    let mut rng = Pcg32::seeded(13);
    let dw = rng.normal_vec(layout.total, 0.1);
    let mut slots: Vec<Option<Packet>> = (0..layout.num_layers()).map(|_| None).collect();
    for _ in 0..steps {
        for (li, slot) in slots.iter_mut().enumerate() {
            if let Some(spent) = slot.take() {
                c.recycle(spent);
            }
            *slot = Some(c.pack_layer(li, layout.view(li, &dw)));
        }
    }
    let payload: usize = slots.iter().map(|s| s.as_ref().unwrap().wire_bytes).sum();
    let analytic = wire::bucket_wire_len(slots.len(), payload);
    let mut frame = Vec::new();
    wire::encode_bucket_frame_packets_into(0, &slots, &mut frame)?;
    let measured = frame.len();
    assert!(
        measured <= analytic,
        "{model}: measured {measured} B > analytic {analytic} B"
    );
    let (bi, decoded) = wire::decode_bucket_frame(&frame)?;
    assert_eq!(bi, 0);
    assert_eq!(decoded.len(), layout.num_layers());
    println!(
        "{:<10} layers {:>3} total {:>9}: measured {:>9} B <= analytic {:>9} B ({:.3}x)",
        model,
        layout.num_layers(),
        layout.total,
        measured,
        analytic,
        analytic as f64 / measured as f64
    );
    Ok(json::obj(vec![
        ("model", json::s(model)),
        ("scheme", json::s("adacomp")),
        ("layers", json::num(layout.num_layers() as f64)),
        ("total_elems", json::num(layout.total as f64)),
        ("measured_bytes", json::num(measured as f64)),
        ("analytic_bytes", json::num(analytic as f64)),
    ]))
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");

    println!("# pack() throughput (per layer call, steady-state residues)");
    println!(
        "{:<10} {:>9} {:>6} {:>12} {:>12} {:>10} {:>8}",
        "scheme", "n", "L_T", "mean", "p95", "Melem/s", "GB/s"
    );
    let pack_shapes: &[(usize, usize)] = if fast {
        &[(25_600, 50)]
    } else {
        &[(25_600, 50), (1_048_576, 50), (1_048_576, 500)]
    };
    for kind in [
        Kind::AdaComp,
        Kind::LocalSelect,
        Kind::Dryden,
        Kind::OneBit,
        Kind::TernGrad,
        Kind::Strom,
        Kind::None,
    ] {
        for &(n, lt) in pack_shapes {
            let iters = if fast {
                5
            } else if n > 500_000 {
                30
            } else {
                200
            };
            let (s, _sent) = bench_scheme(kind, n, lt, iters);
            let melems = s.throughput(n as f64) / 1e6;
            let gbs = s.throughput(n as f64 * 4.0) / 1e9;
            println!(
                "{:<10} {:>9} {:>6} {:>12} {:>12} {:>10.1} {:>8.2}",
                kind.name(),
                n,
                lt,
                fmt_ns(s.mean_ns),
                fmt_ns(s.p95_ns),
                melems,
                gbs
            );
        }
    }

    println!("\n# wire encode+decode per scheme (real exchange-path pass)");
    println!(
        "{:<10} {:>9} {:>6} {:>9} {:>10} {:>10} {:>9} {:>9}",
        "scheme", "n", "L_T", "sent", "measured", "analytic", "encGB/s", "decGB/s"
    );
    let (wn, wlt, witers) = if fast { (25_600, 50, 20) } else { (1_048_576, 500, 50) };
    let mut scheme_rows = Vec::new();
    for kind in [
        Kind::AdaComp,
        Kind::LocalSelect,
        Kind::Dryden,
        Kind::OneBit,
        Kind::TernGrad,
        Kind::Strom,
        Kind::None,
    ] {
        scheme_rows.push(wire_scheme_row(kind, wn, wlt, witers));
    }

    println!("\n# delta-vbyte index codec (SIMD dispatch vs scalar fallback)");
    let vb = vbyte_micro(if fast { 100_000 } else { 1_000_000 }, if fast { 20 } else { 100 });

    println!("\n# adacomp v1 vs v2 (16-bit slot regime)");
    let v2row = adacomp_v2_16bit_row();

    println!("\n# whole-model adacomp bucket frames (measured vs analytic)");
    let steps = if fast { 2 } else { 4 };
    let models = vec![model_row("mnist_dnn", steps)?, model_row("char_lstm", steps)?];

    let doc = json::obj(vec![
        ("schemes", json::arr(scheme_rows)),
        ("vbyte", vb),
        ("adacomp_v2_16bit", v2row),
        ("models", json::arr(models)),
    ]);
    std::fs::write("BENCH_wire.json", doc.to_string())?;
    println!("\nwrote BENCH_wire.json (per-scheme wire throughput, vbyte SIMD-vs-scalar, \
         v1-vs-v2 shrink, per-model measured-vs-analytic bytes)");

    println!("\n# ablation: soft-threshold scale factor (paper studied 1.5-3.0)");
    println!("{:<8} {:>12} {:>14}", "factor", "mean", "sent/bin");
    for factor in [1.5f32, 2.0, 2.5, 3.0] {
        let n = if fast { 65_536 } else { 1_048_576 };
        let layout = Layout::from_specs(&[("w", &[n], LayerKind::Conv)]);
        let cfg = Config {
            lt_override: 50,
            scale_factor: factor,
            ..Config::with_kind(Kind::AdaComp)
        };
        let mut c = compress::build(&cfg, &layout);
        let mut rng = Pcg32::seeded(9);
        let dw = rng.normal_vec(n, 0.1);
        let mut sent = 0usize;
        let samples = time_n(
            || {
                sent = c.pack_layer(0, &dw).sent();
            },
            2,
            if fast { 5 } else { 20 },
        );
        let s = Stats::from(&samples);
        println!(
            "{:<8} {:>12} {:>14.2}",
            factor,
            fmt_ns(s.mean_ns),
            sent as f64 / (n / 50) as f64
        );
    }
    Ok(())
}
