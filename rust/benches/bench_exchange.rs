//! Topology benchmarks: ring vs parameter-server exchange over compressed
//! packets, at several learner counts and sparsity levels — regenerates the
//! bytes/time comparison in EXPERIMENTS.md §Perf and backs the Fig 7b
//! communication story.
//!
//! Times the engine-shaped steady-state path (`exchange_into` with a
//! persistent `Reduced` — zero allocation per round, see
//! rust/tests/alloc_free.rs) and, for contrast, the allocating `exchange`
//! wrapper.
//!
//!   cargo bench --bench bench_exchange

use adacomp::comm::{topology, Fabric, LinkModel, Reduced};
use adacomp::compress::{self, Config, Kind};
use adacomp::models::{LayerKind, Layout};
use adacomp::util::rng::Pcg32;
use adacomp::util::timer::{fmt_ns, time_n, Stats};

fn make_packets(
    layout: &Layout,
    n_learners: usize,
    kind: Kind,
    lt: usize,
) -> Vec<Vec<compress::Packet>> {
    (0..n_learners)
        .map(|l| {
            let cfg = Config {
                lt_override: lt,
                seed: l as u64,
                ..Config::with_kind(kind)
            };
            let mut c = compress::build(&cfg, layout);
            let mut rng = Pcg32::seeded(100 + l as u64);
            (0..layout.num_layers())
                .map(|li| {
                    let dw = rng.normal_vec(layout.layers[li].len(), 0.1);
                    c.pack_layer(li, &dw)
                })
                .collect()
        })
        .collect()
}

fn main() {
    // cifar_cnn-shaped model: 3 conv + fc
    let layout = Layout::from_specs(&[
        ("conv1", &[2400], LayerKind::Conv),
        ("conv2", &[25600], LayerKind::Conv),
        ("conv3", &[51200], LayerKind::Conv),
        ("fc", &[10240], LayerKind::Fc),
    ]);
    let lens: Vec<usize> = layout.layer_lens();

    println!("# exchange: reduce wall time + simulated fabric cost (cifar_cnn-shaped, adacomp lt=50)");
    println!(
        "{:<6} {:>9} {:>12} {:>12} {:>12} {:>14} {:>14} {:>12}",
        "topo", "learners", "into-mean", "into-p95", "alloc-mean", "bytes/round", "sim-time", "dense-equiv"
    );
    for n_learners in [2usize, 8, 32] {
        let packets = make_packets(&layout, n_learners, Kind::AdaComp, 50);
        // sharded/hierarchical variants need at least that many learners
        let topos: &[&str] = if n_learners >= 4 {
            &["ring", "ps", "ps:4", "hier:4"]
        } else {
            &["ring", "ps"]
        };
        for topo_name in topos {
            let mut topo = topology::build(topo_name, n_learners).unwrap();
            let mut fabric = Fabric::new(LinkModel::default());
            // steady state: persistent Reduced, zero-alloc rounds
            let mut reduced = Reduced::new(&lens);
            let samples = time_n(
                || {
                    topo.exchange_into(&packets, &lens, &mut fabric, &mut reduced);
                },
                2,
                50,
            );
            let s = Stats::from(&samples);
            // contrast: the allocating wrapper (fresh Reduced per round)
            let alloc_samples = time_n(
                || {
                    std::hint::black_box(topo.exchange(&packets, &lens, &mut fabric));
                },
                2,
                50,
            );
            let sa = Stats::from(&alloc_samples);
            let rounds = fabric.stats.rounds as f64;
            println!(
                "{:<6} {:>9} {:>12} {:>12} {:>12} {:>14.0} {:>12.3}ms {:>12}",
                topo_name,
                n_learners,
                fmt_ns(s.mean_ns),
                fmt_ns(s.p95_ns),
                fmt_ns(sa.mean_ns),
                fabric.stats.bytes_up as f64 / rounds,
                fabric.stats.sim_time_s / rounds * 1e3,
                fabric.stats.dense_bytes_equiv / fabric.stats.rounds,
            );
        }
    }

    println!("\n# scheme wire cost per round (8 learners, ring)");
    println!(
        "{:<10} {:>14} {:>12} {:>14}",
        "scheme", "bytes/round", "sim-time", "eff-rate"
    );
    for kind in [Kind::AdaComp, Kind::Dryden, Kind::OneBit, Kind::TernGrad, Kind::None] {
        let packets = make_packets(&layout, 8, kind, 50);
        let mut topo = topology::build("ring", 8).unwrap();
        let mut fabric = Fabric::new(LinkModel::default());
        topo.exchange(&packets, &lens, &mut fabric);
        println!(
            "{:<10} {:>14} {:>10.3}ms {:>13.1}x",
            kind.name(),
            fabric.stats.bytes_up,
            fabric.stats.sim_time_s * 1e3,
            fabric.stats.effective_rate(),
        );
    }
}
