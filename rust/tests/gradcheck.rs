//! Finite-difference gradient checks for every `runtime::net::Layer`
//! backward (fc, relu, conv, maxpool, embedding, lstm) on tiny shapes.
//!
//! Each case builds a small `NativeNet` ending in the softmax-xent head,
//! takes the analytic flat gradient from one `step`, and compares a random
//! sample of coordinates against central differences of the loss. Layers
//! are checked both in single-layer nets (isolating their parameter
//! gradients) and composed stacks (exercising their input-gradient `dx`
//! chains).

use std::sync::Arc;

use adacomp::runtime::net::{Conv5x5Same, Embedding, Fc, Layer, Lstm, MaxPool2, NativeNet, Relu};
use adacomp::runtime::{Batch, Executor};
use adacomp::util::rng::Pcg32;

/// Sample `probes` coordinates of the flat gradient and compare against
/// central differences at `eps`.
fn check_grads(net: &mut NativeNet, params: &[f32], batch: &Batch, eps: f32, probes: usize, tag: &str) {
    let out = net.step(params, batch).unwrap();
    assert!(out.loss.is_finite(), "{tag}: non-finite loss");
    assert_eq!(out.grads.len(), params.len(), "{tag}");
    let mut rng = Pcg32::seeded(0xfd + params.len() as u64);
    for _ in 0..probes {
        let i = rng.below(params.len() as u32) as usize;
        let mut pp = params.to_vec();
        pp[i] += eps;
        let mut pm = params.to_vec();
        pm[i] -= eps;
        let lp = net.step(&pp, batch).unwrap().loss;
        let lm = net.step(&pm, batch).unwrap().loss;
        let num = (lp - lm) / (2.0 * eps);
        let ana = out.grads[i];
        assert!(
            (num - ana).abs() < 3e-2_f32.max(0.1 * num.abs()),
            "{tag}: grad[{i}] numerical {num} vs analytic {ana}"
        );
    }
}

fn f32_batch(bsz: usize, elems: usize, labels: Vec<i32>, seed: u64) -> Batch {
    let mut rng = Pcg32::seeded(seed);
    Batch::f32(rng.normal_vec(bsz * elems, 1.0), labels, bsz)
}

#[test]
fn fc_backward() {
    let mut net = NativeNet::new("gc_fc", vec![Arc::new(Fc::new("fc", 7, 4)) as Arc<dyn Layer>], 7, 4);
    let mut rng = Pcg32::seeded(1);
    let params = rng.normal_vec(net.layout().total, 0.4);
    let batch = f32_batch(5, 7, vec![0, 1, 2, 3, 1], 11);
    check_grads(&mut net, &params, &batch, 1e-3, 16, "fc");
}

#[test]
fn fc_relu_chain_backward() {
    // two fc layers with a relu between: perturbing fc1 params exercises
    // Relu::backward and Fc::backward's dx path
    let mut net = NativeNet::new(
        "gc_mlp",
        vec![
            Arc::new(Fc::new("fc1", 6, 5)) as Arc<dyn Layer>,
            Arc::new(Relu),
            Arc::new(Fc::new("fc2", 5, 3)),
        ],
        6,
        4,
    );
    let mut rng = Pcg32::seeded(2);
    let params = rng.normal_vec(net.layout().total, 0.4);
    let batch = f32_batch(4, 6, vec![2, 0, 1, 2], 12);
    check_grads(&mut net, &params, &batch, 1e-3, 16, "fc+relu");
}

#[test]
fn conv_maxpool_backward() {
    // conv -> relu -> pool -> fc: checks Conv5x5Same and MaxPool2 backward
    // plus their dx chains (pool and relu route through argmax/mask)
    let (h, w, cin, cout) = (4usize, 4usize, 2usize, 3usize);
    let mut net = NativeNet::new(
        "gc_cnn",
        vec![
            Arc::new(Conv5x5Same {
                name: "conv1".into(),
                h,
                w,
                cin,
                cout,
            }) as Arc<dyn Layer>,
            Arc::new(Relu),
            Arc::new(MaxPool2 { h, w, c: cout }),
            Arc::new(Fc::new("fc", (h / 2) * (w / 2) * cout, 3)),
        ],
        h * w * cin,
        4,
    );
    let mut rng = Pcg32::seeded(3);
    let params = rng.normal_vec(net.layout().total, 0.3);
    let batch = f32_batch(3, h * w * cin, vec![0, 2, 1], 13);
    // smaller eps: the pooling argmax makes the loss only piecewise smooth,
    // so keep perturbations well inside the current max's margin
    check_grads(&mut net, &params, &batch, 5e-3, 14, "conv+pool");
}

#[test]
fn embedding_backward() {
    let vocab = 9usize;
    let mut net = NativeNet::new(
        "gc_embed",
        vec![Arc::new(Embedding {
            name: "embed".into(),
            vocab,
            dim: 5,
        }) as Arc<dyn Layer>],
        3,
        4,
    );
    let mut rng = Pcg32::seeded(4);
    let params = rng.normal_vec(net.layout().total, 0.5);
    // logits = the gathered rows themselves (head over dim=5 classes)
    let (bsz, t) = (4usize, 3usize);
    let x: Vec<i32> = (0..bsz * t).map(|i| ((i * 5) % vocab) as i32).collect();
    let y: Vec<i32> = (0..bsz * t).map(|i| (i % 5) as i32).collect();
    let batch = Batch::i32(x, y, bsz);
    check_grads(&mut net, &params, &batch, 1e-3, 16, "embedding");
}

#[test]
fn lstm_backward() {
    // f32-input LSTM with an fc head: checks Lstm::backward parameter
    // grads; fc perturbations check nothing new but come along for free
    let (in_dim, hidden) = (4usize, 3usize);
    let mut net = NativeNet::new(
        "gc_lstm",
        vec![
            Arc::new(Lstm {
                name: "lstm1".into(),
                in_dim,
                hidden,
            }) as Arc<dyn Layer>,
            Arc::new(Fc::new("fc", hidden, 4)),
        ],
        0, // in_elems pinned per batch below
        4,
    );
    let (bsz, t) = (3usize, 4usize);
    net.set_in_elems(t * in_dim);
    let mut rng = Pcg32::seeded(5);
    let params = rng.normal_vec(net.layout().total, 0.4);
    let x = rng.normal_vec(bsz * t * in_dim, 1.0);
    let y: Vec<i32> = (0..bsz * t).map(|i| (i % 4) as i32).collect();
    let batch = Batch::f32(x, y, bsz);
    check_grads(&mut net, &params, &batch, 1e-2, 16, "lstm");
}

#[test]
fn full_char_lstm_stack_backward() {
    // the composed recurrent model: embedding -> lstm -> lstm -> fc. This
    // exercises every dx chain of the tentpole stack (fc -> lstm -> lstm ->
    // embedding scatter).
    let vocab = 7usize;
    let mut net = NativeNet::new(
        "gc_char",
        vec![
            Arc::new(Embedding {
                name: "embed".into(),
                vocab,
                dim: 4,
            }) as Arc<dyn Layer>,
            Arc::new(Lstm {
                name: "lstm1".into(),
                in_dim: 4,
                hidden: 5,
            }),
            Arc::new(Lstm {
                name: "lstm2".into(),
                in_dim: 5,
                hidden: 4,
            }),
            Arc::new(Fc::new("fc", 4, vocab)),
        ],
        4,
        4,
    );
    let mut rng = Pcg32::seeded(6);
    let params = rng.normal_vec(net.layout().total, 0.4);
    let (bsz, t) = (3usize, 4usize);
    let x: Vec<i32> = (0..bsz * t).map(|_| rng.below(vocab as u32) as i32).collect();
    let y: Vec<i32> = x.iter().map(|&c| (c + 1) % vocab as i32).collect();
    let batch = Batch::i32(x, y, bsz);
    check_grads(&mut net, &params, &batch, 1e-2, 20, "char-lstm stack");
}
