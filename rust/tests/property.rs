//! Property-style tests (hand-rolled: proptest is not in the vendored crate
//! set; we sweep seeded PCG32 randomness instead, which keeps failures
//! reproducible by construction).
//!
//! Invariants pinned here:
//!   P1  conservation: for every error-feedback scheme, sent + residue' ==
//!       residue + dW (elementwise), across many random steps
//!   P2  adacomp wire roundtrip: encode(decode(p)) == p for random packets
//!   P3  packets are linear: add_into distributes over accumulation
//!   P4  dryden selects an exact top-k by |G|
//!   P5  adacomp selection count >= LS count >= 0 under identical inputs
//!       (the soft threshold only ever *adds* elements)
//!   P6  effective rate accounting: wire rate ~ 4n / bytes for all schemes

use adacomp::compress::{self, wire, Config, Kind};
use adacomp::models::{LayerKind, Layout};
use adacomp::util::rng::Pcg32;

fn one_layer(n: usize) -> Layout {
    Layout::from_specs(&[("w", &[n], LayerKind::Conv)])
}

#[test]
fn p1_conservation_all_feedback_schemes() {
    for kind in [Kind::AdaComp, Kind::LocalSelect, Kind::Dryden, Kind::OneBit, Kind::Strom] {
        for seed in 0..8u64 {
            let mut rng = Pcg32::new(seed, 1);
            let n = 64 + rng.below(2000) as usize;
            let lt = 1 + rng.below(80) as usize;
            let layout = one_layer(n);
            let cfg = Config {
                lt_override: lt,
                strom_tau: 0.05,
                topk_fraction: 0.02,
                seed,
                ..Config::with_kind(kind)
            };
            let mut c = compress::build(&cfg, &layout);
            let mut residue_model = vec![0.0f32; n]; // our own ledger
            for step in 0..6 {
                let dw = rng.normal_vec(n, 0.3);
                let p = c.pack_layer(0, &dw);
                // ledger: residue' = residue + dw - sent
                for (r, &d) in residue_model.iter_mut().zip(dw.iter()) {
                    *r += d;
                }
                let mut sent = vec![0.0f32; n];
                p.add_into(&mut sent);
                for (r, &s) in residue_model.iter_mut().zip(sent.iter()) {
                    *r -= s;
                }
                for (i, (a, b)) in residue_model.iter().zip(c.residue(0).iter()).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-3_f32.max(a.abs() * 1e-4),
                        "{} seed {seed} step {step} i {i}: ledger {a} vs compressor {b}",
                        kind.name()
                    );
                }
            }
        }
    }
}

#[test]
fn p2_wire_roundtrip_random_packets() {
    for seed in 0..20u64 {
        let mut rng = Pcg32::new(seed, 2);
        let lt = [10usize, 50, 63, 64, 500, 5000][rng.below(6) as usize];
        let nbins = 1 + rng.below(40) as usize;
        let n = lt * nbins - rng.below(lt.min(20) as u32) as usize;
        let scale = rng.range(1e-6, 10.0);
        // random strictly-increasing subset with ternary values
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for i in 0..n {
            if rng.uniform() < 0.07 {
                idx.push(i as u32);
                val.push(match rng.below(3) {
                    0 => scale,
                    1 => -scale,
                    _ => 0.0,
                });
            }
        }
        let bytes = wire::encode_adacomp(3, n, lt, scale, &idx, &val).unwrap();
        let p = wire::decode(&bytes).unwrap();
        assert_eq!(p.layer, 3, "seed {seed}");
        assert_eq!(p.n, n);
        assert_eq!(p.idx, idx, "seed {seed}");
        for (a, b) in p.val.iter().zip(val.iter()) {
            assert!((a - b).abs() <= 1e-7 * scale, "seed {seed}");
        }
    }
}

#[test]
fn p3_packet_accumulation_linear() {
    let mut rng = Pcg32::new(9, 3);
    let n = 500;
    let layout = one_layer(n);
    let cfg = Config {
        lt_override: 25,
        ..Config::with_kind(Kind::AdaComp)
    };
    let mut c1 = compress::build(&cfg, &layout);
    let mut c2 = compress::build(&cfg, &layout);
    let dw1 = rng.normal_vec(n, 1.0);
    let dw2 = rng.normal_vec(n, 1.0);
    let p1 = c1.pack_layer(0, &dw1);
    let p2 = c2.pack_layer(0, &dw2);
    // (acc + p1) + p2 == (acc + p2) + p1
    let mut a = vec![0.0f32; n];
    p1.add_into(&mut a);
    p2.add_into(&mut a);
    let mut b = vec![0.0f32; n];
    p2.add_into(&mut b);
    p1.add_into(&mut b);
    assert_eq!(a, b);
}

#[test]
fn p4_dryden_exact_topk() {
    for seed in 0..10u64 {
        let mut rng = Pcg32::new(seed, 4);
        let n = 200 + rng.below(2000) as usize;
        let frac = [0.005f64, 0.01, 0.05][rng.below(3) as usize];
        let layout = one_layer(n);
        let cfg = Config {
            topk_fraction: frac,
            seed,
            ..Config::with_kind(Kind::Dryden)
        };
        let mut c = compress::build(&cfg, &layout);
        let dw = rng.normal_vec(n, 1.0);
        let p = c.pack_layer(0, &dw);
        let k = ((n as f64 * frac).round() as usize).clamp(1, n);
        assert_eq!(p.sent(), k, "seed {seed}");
        let mut mags: Vec<f32> = dw.iter().map(|x| x.abs()).collect();
        mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let kth = mags[k - 1];
        for &i in &p.idx {
            assert!(dw[i as usize].abs() >= kth - 1e-6, "seed {seed}");
        }
    }
}

#[test]
fn p5_soft_threshold_only_adds() {
    for seed in 0..10u64 {
        let mut rng = Pcg32::new(seed, 5);
        let n = 1000;
        let lt = 50;
        let layout = one_layer(n);
        let mk = |kind: Kind| Config {
            lt_override: lt,
            ..Config::with_kind(kind)
        };
        let mut ada = compress::build(&mk(Kind::AdaComp), &layout);
        let mut ls = compress::build(&mk(Kind::LocalSelect), &layout);
        let dw = rng.normal_vec(n, 0.5);
        let pa = ada.pack_layer(0, &dw);
        let pl = ls.pack_layer(0, &dw);
        assert!(
            pa.sent() >= pl.sent().saturating_sub(pl.sent() / 10),
            "seed {seed}: adacomp {} < ls {}",
            pa.sent(),
            pl.sent()
        );
    }
}

#[test]
fn p6_rate_accounting_consistent() {
    for kind in [Kind::AdaComp, Kind::Dryden, Kind::OneBit, Kind::TernGrad, Kind::None] {
        let n = 10_000;
        let layout = one_layer(n);
        let cfg = Config {
            lt_override: 50,
            ..Config::with_kind(kind)
        };
        let mut c = compress::build(&cfg, &layout);
        let mut rng = Pcg32::new(1, 6);
        let dw = rng.normal_vec(n, 1.0);
        let p = c.pack_layer(0, &dw);
        let expect = 4.0 * n as f64 / p.wire_bytes as f64;
        assert!((p.rate_wire() - expect).abs() < 1e-9, "{}", kind.name());
        assert!(p.rate_wire() >= 0.9, "{} rate < 1-ish", kind.name());
        if kind == Kind::OneBit {
            assert!(p.rate_wire() <= 32.0);
        }
        if kind == Kind::TernGrad {
            assert!(p.rate_wire() <= 16.0);
        }
    }
}

#[test]
fn p7_reset_clears_state() {
    for kind in [Kind::AdaComp, Kind::LocalSelect, Kind::Dryden, Kind::OneBit, Kind::Strom] {
        let layout = one_layer(300);
        let cfg = Config {
            lt_override: 30,
            ..Config::with_kind(kind)
        };
        let mut c = compress::build(&cfg, &layout);
        let mut rng = Pcg32::new(3, 7);
        let dw = rng.normal_vec(300, 1.0);
        c.pack_layer(0, &dw);
        c.reset();
        assert!(
            c.residue(0).iter().all(|&x| x == 0.0),
            "{} reset left residue",
            kind.name()
        );
    }
}
