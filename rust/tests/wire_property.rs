//! Seeded randomized property tests for the wire formats: every scheme x
//! slot width x the v2 delta-vbyte sparse forms. Random packets must
//! round-trip bit-exactly, random truncation points must error (never
//! panic, never allocate absurdly), and the SIMD vbyte kernel must produce
//! byte-identical streams to the scalar reference on the same inputs.
//!
//! Run with `ADACOMP_NO_SIMD=1` to force the scalar fallback through the
//! same assertions (CI does both).

use adacomp::compress::{vbyte, wire, Packet};
use adacomp::util::rng::Pcg32;

/// Random strictly-increasing index set over [0, n) with ~`density`
/// fill, plus values drawn by `mkval(rng)`.
fn random_sparse(
    rng: &mut Pcg32,
    n: usize,
    density: f32,
    mut mkval: impl FnMut(&mut Pcg32) -> f32,
) -> (Vec<u32>, Vec<f32>) {
    let mut idx = Vec::new();
    let mut val = Vec::new();
    for i in 0..n {
        if rng.uniform() < density {
            idx.push(i as u32);
            val.push(mkval(rng));
        }
    }
    (idx, val)
}

fn sparse_packet(n: usize, idx: Vec<u32>, val: Vec<f32>) -> Packet {
    Packet {
        layer: 7,
        n,
        idx,
        val,
        wire_bytes: 0,
        paper_bits: 0,
    }
}

fn bits_of(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn distinct_bits(v: &[f32]) -> usize {
    let mut b = bits_of(v);
    b.sort_unstable();
    b.dedup();
    b.len()
}

fn assert_packet_roundtrip(p: &Packet, ctx: &str) -> Vec<u8> {
    let bytes = wire::encode_packet(p).expect(ctx);
    let q = wire::decode(&bytes).expect(ctx);
    assert_eq!(q.layer, p.layer, "{ctx}");
    assert_eq!(q.n, p.n, "{ctx}");
    assert_eq!(q.idx, p.idx, "{ctx}");
    assert_eq!(bits_of(&q.val), bits_of(&p.val), "{ctx}");
    assert_eq!(q.wire_bytes, bytes.len(), "{ctx}");
    bytes
}

#[test]
fn wire_v1_adacomp_random_roundtrip_all_slot_widths() {
    // lt spans the 8-, 16- and 32-bit slot regimes
    for (seed, lt) in [(1u64, 10usize), (2, 63), (3, 64), (4, 500), (5, 16384), (6, 20000)] {
        let mut rng = Pcg32::new(seed, 70);
        let nbins = 1 + rng.below(12) as usize;
        let n = lt * nbins - rng.below(lt.min(40) as u32) as usize;
        let scale = rng.range(1e-5, 4.0);
        let (idx, val) = random_sparse(&mut rng, n, 0.05, |r| match r.below(3) {
            0 => scale,
            1 => -scale,
            _ => 0.0,
        });
        let bytes = wire::encode_adacomp(7, n, lt, scale, &idx, &val).unwrap();
        assert_eq!(bytes.len(), wire::adacomp_wire_len(n, lt, idx.len()), "lt {lt}");
        let q = wire::decode(&bytes).unwrap();
        assert_eq!(q.idx, idx, "lt {lt}");
        assert_eq!(bits_of(&q.val), bits_of(&val), "lt {lt}");
        // truncations error, never panic: exhaustive over the header
        // region, sampled over the (large) slot stream
        for cut in 0..bytes.len().min(64) {
            assert!(wire::decode(&bytes[..cut]).is_err(), "lt {lt} cut {cut}");
        }
        for _ in 0..300 {
            let cut = rng.below(bytes.len() as u32) as usize;
            assert!(wire::decode(&bytes[..cut]).is_err(), "lt {lt} cut {cut}");
        }
    }
}

#[test]
fn wire_v1_sparse_sign_random_roundtrip() {
    for seed in 0..8u64 {
        let mut rng = Pcg32::new(seed, 71);
        let n = 100 + rng.below(5000) as usize;
        let pos = rng.range(1e-4, 2.0);
        let neg = -rng.range(1e-4, 2.0);
        let (idx, _) = random_sparse(&mut rng, n, 0.03, |_| 0.0);
        let signs: Vec<bool> = (0..idx.len()).map(|_| rng.uniform() < 0.5).collect();
        let bytes = wire::encode_sparse_sign(9, n, pos, neg, &idx, |j| signs[j]).unwrap();
        assert_eq!(bytes.len(), wire::sparse_sign_wire_len(idx.len()));
        let q = wire::decode(&bytes).unwrap();
        assert_eq!(q.idx, idx);
        for (j, &v) in q.val.iter().enumerate() {
            assert_eq!(v.to_bits(), if signs[j] { neg } else { pos }.to_bits());
        }
        for cut in 0..bytes.len() {
            assert!(wire::decode(&bytes[..cut]).is_err(), "seed {seed} cut {cut}");
        }
    }
}

#[test]
fn wire_v1_dense_random_roundtrips() {
    for seed in 0..6u64 {
        let mut rng = Pcg32::new(seed, 72);
        let n = 1 + rng.below(700) as usize;

        // onebit: two arbitrary levels
        let pos = rng.range(0.01, 1.0);
        let neg = -rng.range(0.01, 1.0);
        let signs: Vec<bool> = (0..n).map(|_| rng.uniform() < 0.4).collect();
        let bytes = wire::encode_onebit(1, &signs, pos, neg).unwrap();
        assert_eq!(bytes.len(), wire::onebit_wire_len(n));
        let q = wire::decode(&bytes).unwrap();
        for (j, &v) in q.val.iter().enumerate() {
            assert_eq!(v.to_bits(), if signs[j] { neg } else { pos }.to_bits());
        }

        // dense f32: arbitrary bit patterns (including negatives/zeros)
        let vals: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let bytes = wire::encode_dense_f32(2, &vals).unwrap();
        assert_eq!(bytes.len(), wire::dense_f32_wire_len(n));
        let q = wire::decode(&bytes).unwrap();
        assert_eq!(bits_of(&q.val), bits_of(&vals));
        for cut in 0..bytes.len() {
            assert!(wire::decode(&bytes[..cut]).is_err(), "seed {seed} cut {cut}");
        }
    }
}

#[test]
fn wire_v2_random_roundtrip_every_classification() {
    for seed in 0..10u64 {
        let mut rng = Pcg32::new(seed, 73);
        let n = 500 + rng.below(20_000) as usize;
        let scale = rng.range(1e-5, 3.0);

        // ternary: +scale / -scale / 0.0 force the ternary form whenever
        // all three patterns actually land in the draw
        let (idx, val) = random_sparse(&mut rng, n, 0.02, |r| match r.below(3) {
            0 => scale,
            1 => -scale,
            _ => 0.0,
        });
        let three = distinct_bits(&val) == 3;
        let p = sparse_packet(n, idx, val);
        let bytes = assert_packet_roundtrip(&p, "v2 ternary");
        if three {
            assert_eq!(bytes[0], wire::SCHEME_ADACOMP_V2, "seed {seed}");
        }

        // two distinct non-mirror values (not ternary-representable)
        let a = rng.range(0.01, 1.0);
        let b = -rng.range(1.1, 2.0);
        let (idx, val) =
            random_sparse(&mut rng, n, 0.02, |r| if r.uniform() < 0.5 { a } else { b });
        let both = distinct_bits(&val) == 2;
        let p = sparse_packet(n, idx, val);
        let bytes = assert_packet_roundtrip(&p, "v2 two-value");
        if both {
            assert_eq!(bytes[0], wire::SCHEME_SPARSE_SIGN_V2, "seed {seed}");
        }

        // arbitrary f32 payload (fallback), with NaN and -0.0 sprinkled in
        let (idx, val) = random_sparse(&mut rng, n, 0.02, |r| match r.below(8) {
            0 => f32::NAN,
            1 => -0.0,
            _ => r.normal(),
        });
        let p = sparse_packet(n, idx, val);
        let bytes = assert_packet_roundtrip(&p, "v2 f32");

        // truncation on the last (f32) variant exercises the vbyte
        // truncation path plus every v2 payload guard
        for cut in 0..bytes.len() {
            assert!(wire::decode(&bytes[..cut]).is_err(), "seed {seed} cut {cut}");
        }
    }
}

#[test]
fn wire_v2_dense_random_roundtrips() {
    for seed in 0..6u64 {
        let mut rng = Pcg32::new(seed, 74);
        let n = 1 + rng.below(900) as usize;
        let scale = rng.range(1e-4, 2.0);

        // dense ternary values (classified to TERNARY_DENSE or ONEBIT by
        // size; either way the roundtrip must be bit-exact)
        let val: Vec<f32> = (0..n)
            .map(|_| match rng.below(3) {
                0 => scale,
                1 => -scale,
                _ => 0.0,
            })
            .collect();
        assert_packet_roundtrip(&Packet::dense(3, val), "dense ternary");

        // dense two-value
        let a = rng.range(0.01, 1.0);
        let b = -rng.range(1.1, 2.0);
        let val: Vec<f32> = (0..n)
            .map(|i| if (i + seed as usize) % 3 == 0 { a } else { b })
            .collect();
        assert_packet_roundtrip(&Packet::dense(3, val), "dense two-value");

        // dense arbitrary -> v1 DENSE_F32
        let val: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        assert_packet_roundtrip(&Packet::dense(3, val), "dense f32");
    }
}

#[test]
fn wire_bucket_frames_random_roundtrip_and_truncation() {
    for seed in 0..5u64 {
        let mut rng = Pcg32::new(seed, 75);
        let nlayers = 1 + rng.below(6) as usize;
        let mut slots = Vec::new();
        for li in 0..nlayers {
            let n = 10 + rng.below(2000) as usize;
            let scale = rng.range(1e-4, 2.0);
            let (idx, val) = random_sparse(&mut rng, n, 0.05, |r| match r.below(3) {
                0 => scale,
                1 => -scale,
                _ => 0.0,
            });
            let mut p = sparse_packet(n, idx, val);
            p.layer = li;
            slots.push(Some(p));
        }
        let mut frame = Vec::new();
        wire::encode_bucket_frame_packets_into(seed as usize, &slots, &mut frame).unwrap();
        let (bi, decoded) = wire::decode_bucket_frame(&frame).unwrap();
        assert_eq!(bi, seed as usize);
        assert_eq!(decoded.len(), nlayers);
        let payload: usize = decoded.iter().map(|p| p.wire_bytes).sum();
        assert_eq!(wire::bucket_wire_len(nlayers, payload), frame.len());
        for (d, s) in decoded.iter().zip(slots.iter()) {
            let s = s.as_ref().unwrap();
            assert_eq!(d.layer, s.layer);
            assert_eq!(d.idx, s.idx);
            assert_eq!(bits_of(&d.val), bits_of(&s.val));
        }
        // random truncation points error, never panic
        for _ in 0..200 {
            let cut = rng.below(frame.len() as u32) as usize;
            assert!(wire::decode_bucket_frame(&frame[..cut]).is_err(), "cut {cut}");
        }
    }
}

#[test]
fn vbyte_simd_and_scalar_bit_identical_on_random_streams() {
    // dispatch path (SIMD where available, scalar under ADACOMP_NO_SIMD)
    // vs the forced-scalar reference: identical bytes, identical decodes,
    // across gap distributions covering all four varint widths
    for seed in 0..12u64 {
        let mut rng = Pcg32::new(seed, 76);
        let count = rng.below(3000) as usize;
        let max_shift = 1 + rng.below(25); // gap magnitude regime per stream
        let mut idx = Vec::with_capacity(count);
        let mut cur = 0u64;
        for _ in 0..count {
            let gap = 1 + rng.below(1u32 << rng.below(max_shift).min(24)) as u64;
            cur = (cur + gap).min(u32::MAX as u64);
            idx.push(cur as u32);
            if cur == u32::MAX as u64 {
                break;
            }
        }
        let mut fast = Vec::new();
        let mut slow = Vec::new();
        vbyte::encode_into(&idx, &mut fast);
        vbyte::encode_scalar_into(&idx, &mut slow);
        assert_eq!(fast, slow, "seed {seed}");
        assert_eq!(fast.len(), vbyte::encoded_len(&idx), "seed {seed}");

        let mut out_fast = Vec::new();
        let mut out_slow = Vec::new();
        let used_f = vbyte::decode_into(idx.len(), &fast, &mut out_fast).unwrap();
        let used_s = vbyte::decode_scalar_into(idx.len(), &fast, &mut out_slow).unwrap();
        assert_eq!(out_fast, idx, "seed {seed}");
        assert_eq!(out_slow, idx, "seed {seed}");
        assert_eq!(used_f, used_s);
        assert_eq!(used_f, fast.len());

        // truncations error on both paths
        if !fast.is_empty() {
            for _ in 0..50 {
                let cut = rng.below(fast.len() as u32) as usize;
                out_fast.clear();
                out_slow.clear();
                assert!(vbyte::decode_into(idx.len(), &fast[..cut], &mut out_fast).is_err());
                assert!(
                    vbyte::decode_scalar_into(idx.len(), &fast[..cut], &mut out_slow).is_err()
                );
            }
        }
    }
}
