//! Integration over the real AOT path: load manifest + HLO artifacts, run
//! step/eval through PJRT, train a few steps, and exercise the standalone
//! L1 compression graph. Tests skip gracefully when artifacts are missing.
//! The whole file needs the `pjrt` cargo feature (hermetic tier-1 builds
//! compile without the XLA binding — see rust/Cargo.toml).
#![cfg(feature = "pjrt")]

use std::path::Path;

use adacomp::data::{mnist_gen::MnistGen, shakespeare::Shakespeare, Dataset};
use adacomp::models::Manifest;
use adacomp::runtime::pjrt::{compile_hlo, PjrtExecutor};
use adacomp::runtime::{Batch, Executor};

fn artifacts_dir() -> Option<String> {
    let d = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    d.join("manifest.json")
        .exists()
        .then(|| d.to_string_lossy().into_owned())
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

#[test]
fn manifest_loads_all_models() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    assert!(m.models.len() >= 6);
    let cifar = m.model("cifar_cnn").unwrap();
    assert_eq!(cifar.layout.num_layers(), 8);
    assert_eq!(cifar.num_classes, 10);
    let init = m.load_init(cifar).unwrap();
    assert_eq!(init.len(), cifar.layout.total);
    assert!(init.iter().all(|v| v.is_finite()));
}

#[test]
fn mnist_dnn_step_and_eval_run() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let meta = m.model("mnist_dnn").unwrap().clone();
    let params = m.load_init(&meta).unwrap();
    let mut exe = PjrtExecutor::new(&m, "mnist_dnn").unwrap();

    let ds = MnistGen::new(5, 1000, 200);
    let bs = meta.batch;
    let mut batch = Batch::f32(vec![0.0; bs * 784], vec![0; bs], bs);
    let idx: Vec<usize> = (0..bs).collect();
    ds.fill(
        adacomp::data::Split::Train,
        &idx,
        adacomp::data::XBuf::F32(&mut batch.x_f32),
        &mut batch.y,
    );

    let out = exe.step(&params, &batch).unwrap();
    assert!(out.loss.is_finite());
    assert!(out.loss > 1.5 && out.loss < 4.0, "initial loss {}", out.loss);
    assert_eq!(out.grads.len(), params.len());
    assert!(out.grads.iter().any(|g| *g != 0.0));

    let ev = exe.eval(&params, &batch).unwrap();
    assert!(ev.ncorrect >= 0.0 && ev.ncorrect <= bs as f32);
}

#[test]
fn pjrt_gradients_match_finite_difference() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let meta = m.model("mnist_dnn").unwrap().clone();
    let mut params = m.load_init(&meta).unwrap();
    let mut exe = PjrtExecutor::new(&m, "mnist_dnn").unwrap();

    let ds = MnistGen::new(6, 100, 20);
    // smallest exported batch variant
    let bs = *exe.step_batch_sizes().first().unwrap();
    let mut batch = Batch::f32(vec![0.0; bs * 784], vec![0; bs], bs);
    let idx: Vec<usize> = (0..bs).collect();
    ds.fill(
        adacomp::data::Split::Train,
        &idx,
        adacomp::data::XBuf::F32(&mut batch.x_f32),
        &mut batch.y,
    );
    let out = exe.step(&params, &batch).unwrap();
    let eps = 1e-2f32;
    // check two coordinates in the first fc weight
    for &i in &[0usize, 137] {
        let orig = params[i];
        params[i] = orig + eps;
        let lp = exe.step(&params, &batch).unwrap().loss;
        params[i] = orig - eps;
        let lm = exe.step(&params, &batch).unwrap().loss;
        params[i] = orig;
        let num = (lp - lm) / (2.0 * eps);
        let ana = out.grads[i];
        assert!(
            (num - ana).abs() < 2e-2_f32.max(0.2 * num.abs()),
            "grad[{i}] num {num} ana {ana}"
        );
    }
}

#[test]
fn sgd_reduces_loss_through_pjrt() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let meta = m.model("mnist_dnn").unwrap().clone();
    let mut params = m.load_init(&meta).unwrap();
    let mut exe = PjrtExecutor::new(&m, "mnist_dnn").unwrap();
    let ds = MnistGen::new(7, 2000, 200);
    let bs = meta.batch;
    let mut batch = Batch::f32(vec![0.0; bs * 784], vec![0; bs], bs);
    let mut first = 0.0;
    let mut last = 0.0;
    for step in 0..20 {
        let idx: Vec<usize> = (step * bs..(step + 1) * bs).map(|i| i % 2000).collect();
        ds.fill(
            adacomp::data::Split::Train,
            &idx,
            adacomp::data::XBuf::F32(&mut batch.x_f32),
            &mut batch.y,
        );
        let out = exe.step(&params, &batch).unwrap();
        if step == 0 {
            first = out.loss;
        }
        last = out.loss;
        for (p, g) in params.iter_mut().zip(out.grads.iter()) {
            *p -= 0.1 * g;
        }
    }
    assert!(last < first * 0.8, "first {first} last {last}");
}

#[test]
fn char_lstm_int_input_path() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let meta = m.model("char_lstm").unwrap().clone();
    let params = m.load_init(&meta).unwrap();
    let mut exe = PjrtExecutor::new(&m, "char_lstm").unwrap();
    let t = meta.seq_len;
    let ds = Shakespeare::new(1, 30_000, t, 500, 50);
    let bs = meta.batch;
    let mut batch = Batch::i32(vec![0; bs * t], vec![0; bs * t], bs);
    let idx: Vec<usize> = (0..bs).collect();
    ds.fill(
        adacomp::data::Split::Train,
        &idx,
        adacomp::data::XBuf::I32(&mut batch.x_i32),
        &mut batch.y,
    );
    let out = exe.step(&params, &batch).unwrap();
    // initial loss ~ ln(67) = 4.2
    assert!(out.loss > 3.0 && out.loss < 5.5, "loss {}", out.loss);
}

#[test]
fn standalone_adacomp_graph_matches_rust() {
    // The L1 Pallas compression graph (lowered to HLO) must agree with the
    // rust hot-path implementation — three implementations, one semantics.
    let dir = require_artifacts!();
    let path = Path::new(&dir).join("adacomp_n2400_lt50.hlo.txt");
    if !path.exists() {
        eprintln!("skipping: adacomp graph not exported");
        return;
    }
    let exe = compile_hlo(&path).unwrap();
    let n = 2400;
    let lt = 50;
    let mut rng = adacomp::util::rng::Pcg32::seeded(4242);
    let g = rng.normal_vec(n, 0.5);
    let dw = rng.normal_vec(n, 0.2);
    let h: Vec<f32> = g.iter().zip(dw.iter()).map(|(a, b)| a + b).collect();

    let gl = xla::Literal::vec1(&g);
    let hl = xla::Literal::vec1(&h);
    let out = exe.execute::<xla::Literal>(&[gl, hl]).unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let parts = out.to_tuple().unwrap();
    assert_eq!(parts.len(), 3);
    let gq = parts[0].to_vec::<f32>().unwrap();
    let res = parts[1].to_vec::<f32>().unwrap();
    let scale = parts[2].to_vec::<f32>().unwrap()[0];

    // rust pure reference (same as tests/golden.rs transliteration)
    let nbins = n / lt;
    let mut gmax = vec![0.0f32; nbins];
    for b in 0..nbins {
        for i in b * lt..(b + 1) * lt {
            gmax[b] = gmax[b].max(g[i].abs());
        }
    }
    let want_scale = gmax.iter().sum::<f32>() / nbins as f32;
    assert!((scale - want_scale).abs() < 1e-5, "{scale} vs {want_scale}");
    for i in 0..n {
        let b = i / lt;
        let sel = h[i].abs() >= gmax[b] && gmax[b] > 0.0;
        let want_gq = if sel { g[i].signum() * want_scale } else { 0.0 };
        assert!(
            (gq[i] - want_gq).abs() < 1e-5,
            "gq[{i}] {} vs {}",
            gq[i],
            want_gq
        );
        assert!((res[i] - (g[i] - want_gq)).abs() < 1e-5);
    }
}
