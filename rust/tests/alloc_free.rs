//! Zero-allocation guarantee for the exchange/reduce hot path.
//!
//! A counting global allocator wraps `System`; after a warmup round, a
//! steady-state `exchange_into` (both topologies) and a steady-state
//! pack→exchange→recycle loop must perform **zero** heap allocations.
//!
//! NOTE: exactly one #[test] lives in this binary — the default test harness
//! runs tests concurrently in one process, and a second test's allocations
//! would race the counter.

use std::alloc::{GlobalAlloc, Layout as AllocLayout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: AllocLayout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: AllocLayout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: AllocLayout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> usize {
    ALLOCS.load(Ordering::Relaxed)
}

use adacomp::comm::{topology, Fabric, LinkModel, Reduced, Topology};
use adacomp::compress::{self, Config, Kind, Packet};
use adacomp::models::{LayerKind, Layout};
use adacomp::util::rng::Pcg32;

fn layout() -> Layout {
    Layout::from_specs(&[
        ("conv1", &[2400], LayerKind::Conv),
        ("conv2", &[6400], LayerKind::Conv),
        ("fc", &[4096], LayerKind::Fc),
    ])
}

fn packets_for(layout: &Layout, n_learners: usize, kind: Kind) -> Vec<Vec<Packet>> {
    (0..n_learners)
        .map(|l| {
            let cfg = Config {
                lt_override: 50,
                seed: l as u64,
                ..Config::with_kind(kind)
            };
            let mut c = compress::build(&cfg, layout);
            let mut rng = Pcg32::seeded(100 + l as u64);
            (0..layout.num_layers())
                .map(|li| {
                    let dw = rng.normal_vec(layout.layers[li].len(), 0.1);
                    c.pack_layer(li, &dw)
                })
                .collect()
        })
        .collect()
}

#[test]
fn steady_state_exchange_and_pack_are_allocation_free() {
    let layout = layout();
    let lens: Vec<usize> = layout.layers.iter().map(|l| l.len()).collect();

    // --- exchange/reduce: both topologies, fixed packets ------------------
    let per_learner = packets_for(&layout, 4, Kind::AdaComp);
    for name in ["ring", "ps"] {
        let mut topo = topology::build(name).unwrap();
        let mut fabric = Fabric::new(LinkModel::default());
        let mut reduced = Reduced::new(&lens);
        // warmup: sizes internal scratch (ps bitset, up/down vectors)
        for _ in 0..3 {
            topo.exchange_into(&per_learner, &lens, &mut fabric, &mut reduced);
        }
        let before = allocs();
        for _ in 0..50 {
            topo.exchange_into(&per_learner, &lens, &mut fabric, &mut reduced);
        }
        let after = allocs();
        assert_eq!(
            after - before,
            0,
            "{name}: steady-state exchange_into must not allocate"
        );
        assert_eq!(fabric.stats.rounds, 53);
    }

    // --- streamed per-layer exchange: the overlap pipeline's hot path -----
    // The engine's streamed scheduler takes each learner's packet out of its
    // per-(learner, layer) hand-off cell, reduces the layer over the
    // topology (`exchange_layer_into`), and puts the packets back for
    // next-step recycling. Steady state must not allocate.
    {
        use std::sync::Mutex;
        let per_learner = packets_for(&layout, 4, Kind::AdaComp);
        for name in ["ring", "ps"] {
            let mut topo = topology::build(name).unwrap();
            let mut fabric = Fabric::new(LinkModel::default());
            let mut reduced = Reduced::new(&lens);
            let cells: Vec<Vec<Mutex<Option<Packet>>>> = per_learner
                .iter()
                .map(|ps| ps.iter().map(|p| Mutex::new(Some(p.clone()))).collect())
                .collect();
            let mut gather: Vec<Packet> = Vec::with_capacity(4);
            let mut streamed_round = |topo: &mut Box<dyn Topology>,
                                      fabric: &mut Fabric,
                                      reduced: &mut Reduced,
                                      gather: &mut Vec<Packet>| {
                for li in (0..lens.len()).rev() {
                    gather.clear();
                    for learner in &cells {
                        gather.push(learner[li].lock().unwrap().take().unwrap());
                    }
                    topo.exchange_layer_into(li, gather, lens[li], fabric, &mut reduced.sums[li]);
                    for (l, p) in gather.drain(..).enumerate() {
                        *cells[l][li].lock().unwrap() = Some(p);
                    }
                }
            };
            // warmup sizes topology scratch (ps bitset, up/down vectors)
            for _ in 0..3 {
                streamed_round(&mut topo, &mut fabric, &mut reduced, &mut gather);
            }
            let before = allocs();
            for _ in 0..50 {
                streamed_round(&mut topo, &mut fabric, &mut reduced, &mut gather);
            }
            let after = allocs();
            assert_eq!(
                after - before,
                0,
                "{name}: steady-state streamed exchange_layer_into must not allocate"
            );
            // per-layer rounds: one fabric round per layer per step
            assert_eq!(fabric.stats.rounds, 53 * lens.len() as u64);
        }
    }

    // --- pack -> exchange -> recycle: the engine's per-step packet flow ---
    // With recycled buffers the loop settles into zero allocation once the
    // buffer capacities have grown to the high-water packet size. The dense
    // scheme has deterministic packet sizes, which makes the zero assertion
    // exact; sparse schemes share the identical BufPool take/recycle path.
    let mut comps: Vec<Box<dyn compress::Compressor>> = (0..4)
        .map(|l| {
            compress::build(
                &Config {
                    lt_override: 50,
                    seed: l as u64,
                    ..Config::with_kind(Kind::None)
                },
                &layout,
            )
        })
        .collect();
    let dws: Vec<Vec<Vec<f32>>> = (0..4)
        .map(|l| {
            let mut rng = Pcg32::seeded(500 + l as u64);
            (0..layout.num_layers())
                .map(|li| rng.normal_vec(layout.layers[li].len(), 0.1))
                .collect()
        })
        .collect();
    let mut slots: Vec<Vec<Packet>> = (0..4).map(|_| Vec::with_capacity(lens.len())).collect();
    let mut topo = topology::build("ring").unwrap();
    let mut fabric = Fabric::new(LinkModel::default());
    let mut reduced = Reduced::new(&lens);

    let mut round = |comps: &mut Vec<Box<dyn compress::Compressor>>,
                     slots: &mut Vec<Vec<Packet>>,
                     topo: &mut Box<dyn Topology>,
                     fabric: &mut Fabric,
                     reduced: &mut Reduced| {
        for (l, comp) in comps.iter_mut().enumerate() {
            for spent in slots[l].drain(..) {
                comp.recycle(spent);
            }
            for li in 0..lens.len() {
                let p = comp.pack_layer(li, &dws[l][li]);
                slots[l].push(p);
            }
        }
        topo.exchange_into(slots, &lens, fabric, reduced);
    };

    // Warmup: pooled buffers rotate across layers (pool is LIFO), so give
    // every buffer time to visit the largest layer and reach its high-water
    // capacity.
    for _ in 0..8 {
        round(&mut comps, &mut slots, &mut topo, &mut fabric, &mut reduced);
    }
    let before = allocs();
    for _ in 0..16 {
        round(&mut comps, &mut slots, &mut topo, &mut fabric, &mut reduced);
    }
    let after = allocs();
    assert_eq!(
        after - before,
        0,
        "steady-state pack+exchange+recycle must not allocate"
    );
}
