//! Zero-allocation guarantee for the compute + exchange/reduce hot path.
//!
//! A counting global allocator wraps `System`; after a warmup round, a
//! steady-state `exchange_into` (every topology), the bucketed
//! frame-encode→decode→exchange loop (the engine's streamed scheduler
//! shape, including the real wire serialization), a steady-state
//! pack→exchange→recycle loop, and a full forward+backward
//! `step_streamed_into` (mnist_cnn's im2col conv stack and char_lstm's
//! recurrent graph — the executors with the most scratch) must perform
//! **zero** heap allocations.
//!
//! NOTE: exactly one #[test] lives in this binary — the default test harness
//! runs tests concurrently in one process, and a second test's allocations
//! would race the counter.

use std::alloc::{GlobalAlloc, Layout as AllocLayout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: AllocLayout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: AllocLayout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: AllocLayout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> usize {
    ALLOCS.load(Ordering::Relaxed)
}

use adacomp::comm::{topology, Fabric, LinkModel, Reduced, ReducePlan, RoundSched, Topology};
use adacomp::compress::{self, wire, Config, Kind, Packet};
use adacomp::models::{LayerKind, Layout};
use adacomp::runtime::Batch;
use adacomp::train::learner::{cell_ring_for_plan, cells_for_plan, BucketCell, BucketSlots};
use adacomp::util::rng::Pcg32;

/// Every topology the hot path must keep allocation-free (4 learners).
const TOPOLOGIES: &[&str] = &["ring", "ps", "ps:2", "hier:2"];

fn layout() -> Layout {
    Layout::from_specs(&[
        ("conv1", &[2400], LayerKind::Conv),
        ("bias", &[16], LayerKind::Conv),
        ("conv2", &[6400], LayerKind::Conv),
        ("fc", &[4096], LayerKind::Fc),
    ])
}

fn packets_for(layout: &Layout, n_learners: usize, kind: Kind) -> Vec<Vec<Packet>> {
    (0..n_learners)
        .map(|l| {
            let cfg = Config {
                lt_override: 50,
                seed: l as u64,
                ..Config::with_kind(kind)
            };
            let mut c = compress::build(&cfg, layout);
            let mut rng = Pcg32::seeded(100 + l as u64);
            (0..layout.num_layers())
                .map(|li| {
                    let dw = rng.normal_vec(layout.layers[li].len(), 0.1);
                    c.pack_layer(li, &dw)
                })
                .collect()
        })
        .collect()
}

#[test]
fn steady_state_exchange_and_pack_are_allocation_free() {
    let layout = layout();
    let lens: Vec<usize> = layout.layer_lens();

    // --- whole-model barrier exchange: every topology, fixed packets ------
    let per_learner = packets_for(&layout, 4, Kind::AdaComp);
    for name in TOPOLOGIES {
        let mut topo = topology::build(name, 4).unwrap();
        let mut fabric = Fabric::new(LinkModel::default());
        let mut reduced = Reduced::new(&lens);
        // warmup: sizes internal scratch (union bitsets, up/down vectors)
        for _ in 0..3 {
            topo.exchange_into(&per_learner, &lens, &mut fabric, &mut reduced);
        }
        let before = allocs();
        for _ in 0..50 {
            topo.exchange_into(&per_learner, &lens, &mut fabric, &mut reduced);
        }
        let after = allocs();
        assert_eq!(
            after - before,
            0,
            "{name}: steady-state exchange_into must not allocate"
        );
        assert_eq!(fabric.stats.rounds, 53);
    }

    // --- bucketed encode -> decode -> exchange: the streamed scheduler's
    // hot path. Each learner's completed bucket is serialized into the
    // cell's reusable frame buffer (the publish step), the engine decodes
    // the frame into its gather scratch through a pooled BufPool, reduces
    // the decoded packets over the topology (`exchange_bucket_into`), and
    // drains the gather buffers back to the pool. Originals stay in the
    // cell slots. Steady state must not allocate.
    {
        // threshold 12000: bias+conv1 coalesce, conv2 and fc stand alone
        let plan = ReducePlan::build(&layout, 12000, 2);
        assert_eq!(plan.num_buckets(), 3, "fixture should exercise coalescing");
        let per_learner = packets_for(&layout, 4, Kind::AdaComp);
        for name in TOPOLOGIES {
            let mut topo = topology::build(name, 4).unwrap();
            let mut fabric = Fabric::new(LinkModel::default());
            let mut reduced = Reduced::new(&lens);
            let cells: Vec<Vec<BucketCell>> =
                (0..4).map(|_| cells_for_plan(&plan)).collect();
            for (l, packets) in per_learner.iter().enumerate() {
                for (li, p) in packets.iter().enumerate() {
                    let (bi, pos) = plan.slot_of(li);
                    cells[l][bi].lock().slots[pos] = Some(p.clone());
                }
            }
            let mut gather: Vec<Vec<Packet>> =
                (0..4).map(|_| Vec::with_capacity(lens.len())).collect();
            let mut wire_pool = compress::BufPool::default();
            let mut streamed_round = |topo: &mut Box<dyn Topology>,
                                      fabric: &mut Fabric,
                                      reduced: &mut Reduced,
                                      gather: &mut Vec<Vec<Packet>>| {
                for bucket in &plan.buckets {
                    for (l, row) in cells.iter().enumerate() {
                        let mut cell = row[bucket.id].lock();
                        let BucketSlots { slots, frame, .. } = &mut *cell;
                        wire::encode_bucket_frame_packets_into(bucket.id, slots, frame)
                            .unwrap();
                        let fbi =
                            wire::decode_bucket_frame_into(frame, &mut wire_pool, &mut gather[l])
                                .unwrap();
                        assert_eq!(fbi, bucket.id);
                    }
                    topo.exchange_bucket_into(
                        bucket,
                        gather,
                        &lens,
                        RoundSched::default(),
                        fabric,
                        reduced,
                    );
                    for g in gather.iter_mut() {
                        for p in g.drain(..) {
                            wire_pool.put(p.idx, p.val);
                        }
                    }
                }
            };
            // warmup sizes topology scratch (union bitsets, up/down vectors),
            // frame buffers, the decode pool, and the vbyte/simd one-time
            // initialization (shuffle tables, env probe)
            for _ in 0..3 {
                streamed_round(&mut topo, &mut fabric, &mut reduced, &mut gather);
            }
            let before = allocs();
            for _ in 0..50 {
                streamed_round(&mut topo, &mut fabric, &mut reduced, &mut gather);
            }
            let after = allocs();
            assert_eq!(
                after - before,
                0,
                "{name}: steady-state bucketed exchange must not allocate"
            );
            // per-bucket rounds: one fabric round per bucket per step
            assert_eq!(fabric.stats.rounds, 53 * plan.num_buckets() as u64);
        }
    }

    // --- windowed (K = 2) slot-ring loop: the bounded-staleness engine's
    // steady state. Three step slots are in flight at once; each step packs
    // into its slot's cells (recycling the packets the slot held K + 1
    // steps ago through the compressor pool), the engine exchanges every
    // bucket with ready-time placement on the per-port timeline, and hands
    // the packets back to the same slot. Once every slot has cycled and
    // the pool reached its high-water capacity, the loop must not allocate.
    {
        const WINDOW: usize = 3; // --staleness 2
        let plan = ReducePlan::build(&layout, 12000, 2);
        assert_eq!(plan.num_buckets(), 3, "fixture should exercise coalescing");
        // dense scheme: deterministic packet sizes make the zero assertion
        // exact; sparse schemes share the identical BufPool path
        let mut comps: Vec<Box<dyn compress::Compressor>> = (0..4)
            .map(|l| {
                compress::build(
                    &Config {
                        lt_override: 50,
                        seed: l as u64,
                        ..Config::with_kind(Kind::None)
                    },
                    &layout,
                )
            })
            .collect();
        let dws: Vec<Vec<Vec<f32>>> = (0..4)
            .map(|l| {
                let mut rng = Pcg32::seeded(900 + l as u64);
                (0..layout.num_layers())
                    .map(|li| rng.normal_vec(layout.layers[li].len(), 0.1))
                    .collect()
            })
            .collect();
        let rings: Vec<Vec<Vec<BucketCell>>> =
            (0..4).map(|_| cell_ring_for_plan(&plan, WINDOW)).collect();
        let mut topo = topology::build("ps:2", 4).unwrap();
        let mut fabric = Fabric::new(LinkModel::default());
        let mut reduced = Reduced::new(&lens);
        let mut gather: Vec<Vec<Packet>> =
            (0..4).map(|_| Vec::with_capacity(lens.len())).collect();
        let mut wire_pool = compress::BufPool::default();
        let mut port_end = vec![0.0f64; 2];

        let mut windowed_step = |step: usize,
                                 comps: &mut Vec<Box<dyn compress::Compressor>>,
                                 topo: &mut Box<dyn Topology>,
                                 fabric: &mut Fabric,
                                 reduced: &mut Reduced,
                                 gather: &mut Vec<Vec<Packet>>,
                                 port_end: &mut Vec<f64>| {
            let slot = step % WINDOW;
            // learner phase: recycle the slot's previous occupancy, pack
            // fresh packets into the slot's cells
            for (l, comp) in comps.iter_mut().enumerate() {
                for cell in rings[l][slot].iter() {
                    let mut cell = cell.lock();
                    cell.filled = 0;
                    for s in cell.slots.iter_mut() {
                        if let Some(spent) = s.take() {
                            comp.recycle(spent);
                        }
                    }
                }
                for li in 0..lens.len() {
                    let p = comp.pack_layer(li, &dws[l][li]);
                    let (bi, pos) = plan.slot_of(li);
                    let mut cell = rings[l][slot][bi].lock();
                    cell.slots[pos] = Some(p);
                    cell.filled += 1;
                }
            }
            // engine phase: serialize each bucket into its cell's frame
            // (publish), decode through the pooled buffers, exchange at the
            // bucket's ready time, then return the decode buffers to the
            // pool. Originals stay in the slots for next-occupancy recycle.
            let ready_s = step as f64 * 1e-3;
            for bucket in &plan.buckets {
                for (l, ring) in rings.iter().enumerate() {
                    let mut cell = ring[slot][bucket.id].lock();
                    let BucketSlots { slots, frame, .. } = &mut *cell;
                    wire::encode_bucket_frame_packets_into(bucket.id, slots, frame).unwrap();
                    let fbi =
                        wire::decode_bucket_frame_into(frame, &mut wire_pool, &mut gather[l])
                            .unwrap();
                    assert_eq!(fbi, bucket.id);
                }
                let cost = topo.exchange_bucket_into(
                    bucket,
                    gather,
                    &lens,
                    RoundSched {
                        ready_s,
                        port_free_s: port_end[bucket.port],
                    },
                    fabric,
                    reduced,
                );
                port_end[bucket.port] = cost.end_s;
                for g in gather.iter_mut() {
                    for p in g.drain(..) {
                        wire_pool.put(p.idx, p.val);
                    }
                }
            }
        };

        // warmup: every slot cycles several times so the compressor pools
        // reach their high-water capacity across the ring
        let mut step = 0usize;
        for _ in 0..4 * WINDOW {
            windowed_step(
                step, &mut comps, &mut topo, &mut fabric, &mut reduced, &mut gather,
                &mut port_end,
            );
            step += 1;
        }
        let before = allocs();
        for _ in 0..10 * WINDOW {
            windowed_step(
                step, &mut comps, &mut topo, &mut fabric, &mut reduced, &mut gather,
                &mut port_end,
            );
            step += 1;
        }
        let after = allocs();
        assert_eq!(
            after - before,
            0,
            "windowed (K=2) slot-ring exchange must not allocate in steady state"
        );
        assert_eq!(
            fabric.stats.rounds,
            (14 * WINDOW * plan.num_buckets()) as u64
        );
    }

    // --- pack -> exchange -> recycle: the engine's per-step packet flow ---
    // With recycled buffers the loop settles into zero allocation once the
    // buffer capacities have grown to the high-water packet size. The dense
    // scheme has deterministic packet sizes, which makes the zero assertion
    // exact; sparse schemes share the identical BufPool take/recycle path.
    let mut comps: Vec<Box<dyn compress::Compressor>> = (0..4)
        .map(|l| {
            compress::build(
                &Config {
                    lt_override: 50,
                    seed: l as u64,
                    ..Config::with_kind(Kind::None)
                },
                &layout,
            )
        })
        .collect();
    let dws: Vec<Vec<Vec<f32>>> = (0..4)
        .map(|l| {
            let mut rng = Pcg32::seeded(500 + l as u64);
            (0..layout.num_layers())
                .map(|li| rng.normal_vec(layout.layers[li].len(), 0.1))
                .collect()
        })
        .collect();
    let mut slots: Vec<Vec<Packet>> = (0..4).map(|_| Vec::with_capacity(lens.len())).collect();
    let mut topo = topology::build("ring", 4).unwrap();
    let mut fabric = Fabric::new(LinkModel::default());
    let mut reduced = Reduced::new(&lens);

    let mut round = |comps: &mut Vec<Box<dyn compress::Compressor>>,
                     slots: &mut Vec<Vec<Packet>>,
                     topo: &mut Box<dyn Topology>,
                     fabric: &mut Fabric,
                     reduced: &mut Reduced| {
        for (l, comp) in comps.iter_mut().enumerate() {
            for spent in slots[l].drain(..) {
                comp.recycle(spent);
            }
            for li in 0..lens.len() {
                let p = comp.pack_layer(li, &dws[l][li]);
                slots[l].push(p);
            }
        }
        topo.exchange_into(slots, &lens, fabric, reduced);
    };

    // Warmup: pooled buffers rotate across layers (pool is LIFO), so give
    // every buffer time to visit the largest layer and reach its high-water
    // capacity.
    for _ in 0..8 {
        round(&mut comps, &mut slots, &mut topo, &mut fabric, &mut reduced);
    }
    let before = allocs();
    for _ in 0..16 {
        round(&mut comps, &mut slots, &mut topo, &mut fabric, &mut reduced);
    }
    let after = allocs();
    assert_eq!(
        after - before,
        0,
        "steady-state pack+exchange+recycle must not allocate"
    );

    // --- full fwd/bwd step: the compute hot path. step_streamed_into
    // writes into a caller-owned grads buffer; the conv im2col/dcols
    // buffers, the packed-GEMM panels, the LSTM gate scratch, and the
    // backward dy/dx ping-pong all live in the executor's KernelScratch
    // arena, so after warmup a whole training step allocates nothing.
    // mnist_cnn (two im2col conv stages) and char_lstm (recurrent graph,
    // 50 timesteps) carry the most scratch of the native models.
    //
    // Run with a kernel-thread budget of 2 so the parallel GEMM path is the
    // one measured: the conv im2col GEMMs cross MIN_PAR_FLOPS and fan out
    // over the compute pool. Pool helpers spawn and the task queue +
    // scratch shards reach capacity during warmup; steady state must then
    // stay at zero even with tiles crossing threads.
    adacomp::tensor::parallel::set_kernel_threads(2);
    for model in ["mnist_cnn", "char_lstm"] {
        let spec = adacomp::harness::native_spec(model, 11, 8).unwrap();
        let mut exec = spec.factory.build_worker().unwrap();
        let bsz = 8usize;
        let mut rng = Pcg32::seeded(77);
        let batch = if spec.x_is_int {
            let x: Vec<i32> = (0..bsz * spec.x_elems)
                .map(|_| rng.below(spec.num_classes as u32) as i32)
                .collect();
            let y: Vec<i32> = (0..bsz * spec.y_elems)
                .map(|_| rng.below(spec.num_classes as u32) as i32)
                .collect();
            Batch::i32(x, y, bsz)
        } else {
            let x = rng.normal_vec(bsz * spec.x_elems, 1.0);
            let y: Vec<i32> = (0..bsz * spec.y_elems)
                .map(|_| rng.below(spec.num_classes as u32) as i32)
                .collect();
            Batch::f32(x, y, bsz)
        };
        let mut grads = Vec::new();
        // warmup: activations/tapes/scratch grow to this batch shape, the
        // grads buffer reaches layout.total, simd gates probe the env
        for _ in 0..3 {
            exec.step_streamed_into(&spec.init, &batch, &mut grads, &mut |_, _| {})
                .unwrap();
        }
        let before = allocs();
        for _ in 0..10 {
            let loss = exec
                .step_streamed_into(&spec.init, &batch, &mut grads, &mut |_, _| {})
                .unwrap();
            assert!(loss.is_finite());
        }
        let after = allocs();
        assert_eq!(
            after - before,
            0,
            "{model}: steady-state fwd/bwd step_streamed_into must not allocate"
        );
    }
}
