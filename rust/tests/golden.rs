//! Cross-language contract: the rust AdaComp hot path must be bit-compatible
//! with the python oracle (ref.py). `aot.py` dumps golden vectors; this test
//! replays them through `compress::adacomp`.
//!
//! Skips (with a note) when artifacts/ has not been built.

use adacomp::compress::{adacomp::AdaComp, Compressor, Config, Kind};
use adacomp::models::{LayerKind, Layout};
use adacomp::util::json::Json;

fn golden_path() -> Option<std::path::PathBuf> {
    let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/golden_adacomp.json");
    p.exists().then_some(p)
}

#[test]
fn rust_adacomp_matches_python_ref() {
    let Some(path) = golden_path() else {
        eprintln!("skipping: run `make artifacts` to generate golden vectors");
        return;
    };
    let txt = std::fs::read_to_string(path).unwrap();
    let v = Json::from_str_slice(&txt).unwrap();
    let cases = v.get("cases").as_arr().unwrap();
    assert!(cases.len() >= 5);
    for (ci, case) in cases.iter().enumerate() {
        let n = case.get("n").as_usize().unwrap();
        let lt = case.get("lt").as_usize().unwrap();
        let g = case.get("g").f32_vec().unwrap();
        let h = case.get("h").f32_vec().unwrap();
        let want_gq = case.get("gq").f32_vec().unwrap();
        let want_res = case.get("residue").f32_vec().unwrap();
        let want_mask = case.get("mask").usize_vec().unwrap();
        let want_scale = case.get("scale").as_f64().unwrap() as f32;

        // python's G is residue+dW and H = G + dW => dW = h - g. The pure
        // transliteration below takes (G, dW) explicitly; the stateful
        // compressor is checked against the same transliteration across
        // accumulation steps in `stateful_matches_pure_over_steps`.
        let dw: Vec<f32> = h.iter().zip(g.iter()).map(|(hi, gi)| hi - gi).collect();
        let got = adacomp_pure(&g, &dw, lt);
        assert_eq!(got.mask, want_mask, "case {ci} mask");
        assert_close(&got.gq, &want_gq, 1e-6, &format!("case {ci} gq"));
        assert_close(&got.residue, &want_res, 1e-6, &format!("case {ci} residue"));
        assert!(
            (got.scale - want_scale).abs() <= 1e-6 * want_scale.abs().max(1.0),
            "case {ci} scale {} vs {}",
            got.scale,
            want_scale
        );

        // Conservation also holds for the stateful compressor on fresh input.
        let layout = Layout::from_specs(&[("w", &[n], LayerKind::Fc)]);
        let cfg = Config {
            lt_override: lt,
            ..Config::with_kind(Kind::AdaComp)
        };
        let mut c = AdaComp::new(&cfg, &layout);
        let p = c.pack_layer(0, &g);
        let mut recon = c.residue(0).to_vec();
        p.add_into(&mut recon);
        for (a, b) in recon.iter().zip(g.iter()) {
            assert!((a - b).abs() < 1e-5, "case {ci} conservation");
        }
    }
}

struct PureOut {
    gq: Vec<f32>,
    residue: Vec<f32>,
    mask: Vec<usize>,
    scale: f32,
}

/// Direct transliteration of ref.py (G and dW given explicitly), used to
/// compare against golden vectors without residue-preloading gymnastics.
fn adacomp_pure(g: &[f32], dw: &[f32], lt: usize) -> PureOut {
    let n = g.len();
    let nbins = n.div_ceil(lt);
    let mut gmax = vec![0.0f32; nbins];
    for b in 0..nbins {
        let hi = ((b + 1) * lt).min(n);
        for i in b * lt..hi {
            gmax[b] = gmax[b].max(g[i].abs());
        }
    }
    let scale = gmax.iter().sum::<f32>() / nbins as f32;
    let mut gq = vec![0.0f32; n];
    let mut residue = g.to_vec();
    let mut mask = vec![0usize; n];
    for b in 0..nbins {
        if gmax[b] <= 0.0 {
            continue;
        }
        let hi = ((b + 1) * lt).min(n);
        for i in b * lt..hi {
            let h = g[i] + dw[i];
            if h.abs() >= gmax[b] {
                mask[i] = 1;
                let sent = if g[i] > 0.0 {
                    scale
                } else if g[i] < 0.0 {
                    -scale
                } else {
                    0.0
                };
                gq[i] = sent;
                residue[i] = g[i] - sent;
            }
        }
    }
    PureOut {
        gq,
        residue,
        mask,
        scale,
    }
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what} length");
    for (i, (a, b)) in got.iter().zip(want.iter()).enumerate() {
        assert!(
            (a - b).abs() <= tol * b.abs().max(1.0),
            "{what}[{i}]: {a} vs {b}"
        );
    }
}

/// The stateful AdaComp must agree with the pure transliteration across
/// multiple accumulation steps (residue carried correctly).
#[test]
fn stateful_matches_pure_over_steps() {
    use adacomp::util::rng::Pcg32;
    let n = 777;
    let lt = 50;
    let layout = Layout::from_specs(&[("w", &[n], LayerKind::Conv)]);
    let cfg = Config {
        lt_override: lt,
        ..Config::with_kind(Kind::AdaComp)
    };
    let mut stateful = AdaComp::new(&cfg, &layout);
    let mut residue = vec![0.0f32; n];
    let mut rng = Pcg32::seeded(99);
    for step in 0..20 {
        let dw = rng.normal_vec(n, 0.1);
        let g: Vec<f32> = residue.iter().zip(dw.iter()).map(|(r, d)| r + d).collect();
        let pure = adacomp_pure(&g, &dw, lt);
        let p = stateful.pack_layer(0, &dw);
        // same selection, same values
        let got_mask: Vec<usize> = {
            let mut m = vec![0usize; n];
            for &i in &p.idx {
                m[i as usize] = 1;
            }
            m
        };
        assert_eq!(got_mask, pure.mask, "step {step}");
        assert_close(stateful.residue(0), &pure.residue, 1e-5, "residue");
        residue = pure.residue;
    }
}
