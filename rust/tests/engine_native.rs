//! Integration: the full coordinator loop (engine + learners + compression +
//! topology + optimizer) over the hermetic native executor — no artifacts
//! needed.

use adacomp::comm::LinkModel;
use adacomp::compress::{Config, Kind};
use adacomp::data::synth::GaussianMixture;
use adacomp::optim::LrSchedule;
use adacomp::runtime::native::NativeMlp;
use adacomp::train::{Engine, TrainConfig};

fn base_cfg(kind: Kind, learners: usize) -> TrainConfig {
    TrainConfig {
        run_name: format!("test-{}", kind.name()),
        model_name: "native_mlp".into(),
        n_learners: learners,
        batch_per_learner: 16,
        epochs: 6,
        steps_per_epoch: 25,
        lr: LrSchedule::Constant(0.1),
        optimizer: "sgd".into(),
        momentum: 0.9,
        compression: Config {
            lt_override: 10,
            ..Config::with_kind(kind)
        },
        topology: "ring".into(),
        link: LinkModel::default(),
        seed: 7,
        // keep the tiny test model multi-bucket (w1 stands alone, the rest
        // coalesce) so the streamed pipeline has something to overlap; the
        // auto threshold would coalesce the whole model into one bucket
        bucket_bytes: 600,
        ..TrainConfig::default()
    }
}

/// Every topology spec the matrix tests sweep (4 learners).
const TOPOLOGIES: &[&str] = &["ps", "ps:4", "hier:4", "ring"];

fn train(kind: Kind, learners: usize, topology: &str) -> adacomp::metrics::RunRecord {
    let ds = GaussianMixture::new(3, 16, 4, 800, 200, 0.6);
    let exe = NativeMlp::new(&[16, 32, 4], 50);
    let params = exe.init_params(11);
    let layout = exe.layout().clone();
    let mut cfg = base_cfg(kind, learners);
    cfg.topology = topology.into();
    let mut engine = Engine::new(&exe, &ds, &layout);
    engine.run(&cfg, &params).expect("run")
}

/// Same run at an explicit worker-thread count.
fn train_threads(kind: Kind, learners: usize, threads: usize) -> adacomp::metrics::RunRecord {
    train_mode(kind, learners, threads, "streamed")
}

/// Same run at an explicit thread count and exchange mode.
fn train_mode(
    kind: Kind,
    learners: usize,
    threads: usize,
    exchange: &str,
) -> adacomp::metrics::RunRecord {
    let ds = GaussianMixture::new(3, 16, 4, 800, 200, 0.6);
    let exe = NativeMlp::new(&[16, 32, 4], 50);
    let params = exe.init_params(11);
    let layout = exe.layout().clone();
    let mut cfg = base_cfg(kind, learners);
    cfg.threads = threads;
    cfg.exchange = exchange.into();
    let mut engine = Engine::new(&exe, &ds, &layout);
    engine.run(&cfg, &params).expect("run")
}

/// Short run with every knob explicit (the topology-matrix tests).
fn train_matrix(
    kind: Kind,
    threads: usize,
    topology: &str,
    exchange: &str,
) -> adacomp::metrics::RunRecord {
    train_window(kind, threads, topology, exchange, 0, 0.0)
}

/// The full knob matrix including the bounded-staleness window.
fn train_window(
    kind: Kind,
    threads: usize,
    topology: &str,
    exchange: &str,
    staleness: usize,
    jitter: f64,
) -> adacomp::metrics::RunRecord {
    let ds = GaussianMixture::new(3, 16, 4, 800, 200, 0.6);
    let exe = NativeMlp::new(&[16, 32, 4], 50);
    let params = exe.init_params(11);
    let layout = exe.layout().clone();
    let mut cfg = base_cfg(kind, 4);
    cfg.epochs = 2;
    cfg.steps_per_epoch = 12;
    cfg.threads = threads;
    cfg.topology = topology.into();
    cfg.exchange = exchange.into();
    cfg.staleness = staleness;
    cfg.link.jitter = jitter;
    let mut engine = Engine::new(&exe, &ds, &layout);
    engine.run(&cfg, &params).expect("run")
}

/// Assert two runs have bit-identical per-epoch losses and test errors.
fn assert_epochs_bitwise(
    a: &adacomp::metrics::RunRecord,
    b: &adacomp::metrics::RunRecord,
    what: &str,
) {
    assert_eq!(a.epochs.len(), b.epochs.len(), "{what}");
    for (ea, eb) in a.epochs.iter().zip(b.epochs.iter()) {
        assert_eq!(
            ea.train_loss.to_bits(),
            eb.train_loss.to_bits(),
            "{what} epoch {}: {} vs {}",
            ea.epoch,
            ea.train_loss,
            eb.train_loss
        );
        assert_eq!(
            ea.test_error_pct.to_bits(),
            eb.test_error_pct.to_bits(),
            "{what} epoch {}",
            ea.epoch
        );
    }
}

#[test]
fn baseline_learns() {
    let rec = train(Kind::None, 1, "ring");
    assert!(!rec.diverged);
    assert!(
        rec.final_test_error() < 15.0,
        "baseline err {}",
        rec.final_test_error()
    );
}

#[test]
fn adacomp_matches_baseline_accuracy() {
    let base = train(Kind::None, 2, "ring");
    let comp = train(Kind::AdaComp, 2, "ring");
    assert!(!comp.diverged);
    // paper claim: negligible degradation
    assert!(
        comp.final_test_error() <= base.final_test_error() + 6.0,
        "adacomp {} vs baseline {}",
        comp.final_test_error(),
        base.final_test_error()
    );
    // and it actually compresses
    assert!(
        comp.mean_rate_wire() > 5.0,
        "rate {}",
        comp.mean_rate_wire()
    );
}

#[test]
fn topologies_equivalent_semantics() {
    // ring and PS must produce identical training trajectories (same sums)
    let a = train(Kind::AdaComp, 4, "ring");
    let b = train(Kind::AdaComp, 4, "ps");
    let la: Vec<f64> = a.epochs.iter().map(|e| e.train_loss).collect();
    let lb: Vec<f64> = b.epochs.iter().map(|e| e.train_loss).collect();
    for (x, y) in la.iter().zip(lb.iter()) {
        assert!((x - y).abs() < 1e-9, "{x} vs {y}");
    }
    // but different byte profiles
    assert_ne!(a.fabric.bytes_up, b.fabric.bytes_up);
}

#[test]
fn multi_learner_compression_rate_improves() {
    // paper Fig 7b: more learners (smaller per-learner batches here mean
    // noisier per-learner gradients) — just assert the run completes and
    // compresses at both scales; the quantitative sweep lives in examples/.
    let one = train(Kind::AdaComp, 1, "ring");
    let eight = train(Kind::AdaComp, 8, "ring");
    assert!(!one.diverged && !eight.diverged);
    assert!(eight.mean_rate_wire() > 3.0);
}

#[test]
fn all_schemes_run_to_completion() {
    for kind in [
        Kind::AdaComp,
        Kind::LocalSelect,
        Kind::Dryden,
        Kind::OneBit,
        Kind::TernGrad,
        Kind::Strom,
        Kind::None,
    ] {
        let rec = train(kind, 2, "ring");
        assert_eq!(rec.epochs.len(), 6, "{} did not finish", kind.name());
        assert!(rec.epochs.iter().all(|e| e.train_loss.is_finite()));
    }
}

#[test]
fn deterministic_given_seed() {
    let a = train(Kind::AdaComp, 2, "ring");
    let b = train(Kind::AdaComp, 2, "ring");
    assert_eq!(a.final_test_error(), b.final_test_error());
    assert_eq!(a.fabric.bytes_up, b.fabric.bytes_up);
}

#[test]
fn parallel_matches_sequential_bitwise() {
    // The engine's determinism contract (DESIGN.md §Threading): the same
    // TrainConfig + seed must produce bit-identical losses and wire bytes at
    // every worker-thread count — the parallel fan-out may not perturb the
    // float reduction order or any learner's private state.
    for kind in [Kind::AdaComp, Kind::None] {
        let seq = train_threads(kind, 4, 1);
        let par = train_threads(kind, 4, 4);
        assert_eq!(seq.epochs.len(), par.epochs.len(), "{}", kind.name());
        for (a, b) in seq.epochs.iter().zip(par.epochs.iter()) {
            assert_eq!(
                a.train_loss.to_bits(),
                b.train_loss.to_bits(),
                "{} epoch {}: threads=1 loss {} vs threads=4 loss {}",
                kind.name(),
                a.epoch,
                a.train_loss,
                b.train_loss
            );
            assert_eq!(a.test_error_pct.to_bits(), b.test_error_pct.to_bits());
        }
        assert_eq!(seq.fabric.bytes_up, par.fabric.bytes_up, "{}", kind.name());
        assert_eq!(seq.fabric.bytes_down, par.fabric.bytes_down);
        assert_eq!(seq.fabric.rounds, par.fabric.rounds);
    }
    // oversubscription (threads > learners) must also be identical
    let seq = train_threads(Kind::AdaComp, 3, 1);
    let over = train_threads(Kind::AdaComp, 3, 8);
    assert_eq!(seq.epochs.last().unwrap().train_loss.to_bits(),
               over.epochs.last().unwrap().train_loss.to_bits());
    assert_eq!(seq.fabric.bytes_up, over.fabric.bytes_up);
}

#[test]
fn streamed_matches_barrier_bitwise() {
    // The overlap pipeline's determinism contract (DESIGN.md §Overlap
    // pipeline): `--exchange streamed` must equal `--exchange barrier`
    // bit-for-bit — per-bucket packets are identical and the reduce
    // consumes them in learner-id order — at every thread count. Both
    // modes now pack during backward in the same order, so even terngrad
    // (cross-layer RNG stream while packing) is bit-equal across modes.
    for kind in [Kind::AdaComp, Kind::None, Kind::TernGrad] {
        for threads in [1usize, 4] {
            let b = train_mode(kind, 4, threads, "barrier");
            let s = train_mode(kind, 4, threads, "streamed");
            assert_eq!(b.epochs.len(), s.epochs.len(), "{}", kind.name());
            for (eb, es) in b.epochs.iter().zip(s.epochs.iter()) {
                assert_eq!(
                    eb.train_loss.to_bits(),
                    es.train_loss.to_bits(),
                    "{} threads={threads} epoch {}: barrier loss {} vs streamed loss {}",
                    kind.name(),
                    eb.epoch,
                    eb.train_loss,
                    es.train_loss
                );
                assert_eq!(eb.test_error_pct.to_bits(), es.test_error_pct.to_bits());
            }
            // identical payloads cross the wire either way; only the
            // message granularity (and thus sim time) differs
            assert_eq!(b.fabric.bytes_up, s.fabric.bytes_up, "{}", kind.name());
            assert_eq!(b.fabric.bytes_down, s.fabric.bytes_down, "{}", kind.name());
        }
    }
}

#[test]
fn topologies_bitwise_identical_across_modes_and_threads() {
    // The reduce-plan determinism contract (ISSUE 4 acceptance): final
    // results are bit-identical for every topology × exchange mode ×
    // thread count — reduction stays in learner-id order within each
    // bucket, and the simulated shard/rack/ring structure shapes only the
    // timeline. Wire bytes are identical across modes and threads *within*
    // a topology (same bucket messages, different placement).
    let mut reference: Option<adacomp::metrics::RunRecord> = None;
    for topo in TOPOLOGIES {
        let mut topo_bytes: Option<(u64, u64)> = None;
        for exchange in ["streamed", "barrier"] {
            for threads in [1usize, 4] {
                let r = train_matrix(Kind::AdaComp, threads, topo, exchange);
                assert!(!r.diverged, "{topo}/{exchange}/t{threads}");
                match &reference {
                    None => reference = Some(r.clone()),
                    Some(exp) => {
                        assert_eq!(exp.epochs.len(), r.epochs.len());
                        for (a, b) in exp.epochs.iter().zip(r.epochs.iter()) {
                            assert_eq!(
                                a.train_loss.to_bits(),
                                b.train_loss.to_bits(),
                                "{topo}/{exchange}/t{threads} epoch {}: {} vs {}",
                                a.epoch,
                                a.train_loss,
                                b.train_loss
                            );
                            assert_eq!(
                                a.test_error_pct.to_bits(),
                                b.test_error_pct.to_bits(),
                                "{topo}/{exchange}/t{threads}"
                            );
                        }
                    }
                }
                match &topo_bytes {
                    None => topo_bytes = Some((r.fabric.bytes_up, r.fabric.bytes_down)),
                    Some(&(up, down)) => {
                        assert_eq!(r.fabric.bytes_up, up, "{topo}/{exchange}/t{threads}");
                        assert_eq!(r.fabric.bytes_down, down, "{topo}/{exchange}/t{threads}");
                    }
                }
            }
        }
    }
}

#[test]
fn staleness_zero_matches_synchronous_bitwise() {
    // ISSUE 5 acceptance: `--staleness 0` IS the synchronous engine —
    // bit-identical trajectories across ps/ring × streamed/barrier × 1/4
    // threads, with K = 0 explicit, and jitter must be timeline-only (a
    // jittered K = 0 run is bit-equal to the unjittered one).
    let reference = train_matrix(Kind::AdaComp, 1, "ps", "streamed");
    for topo in ["ps", "ring"] {
        for exchange in ["streamed", "barrier"] {
            for threads in [1usize, 4] {
                let r = train_window(Kind::AdaComp, threads, topo, exchange, 0, 0.0);
                assert!(!r.diverged, "{topo}/{exchange}/t{threads}");
                assert_epochs_bitwise(
                    &reference,
                    &r,
                    &format!("K=0 {topo}/{exchange}/t{threads}"),
                );
                let jittered = train_window(Kind::AdaComp, threads, topo, exchange, 0, 0.3);
                assert_epochs_bitwise(
                    &r,
                    &jittered,
                    &format!("K=0+jitter {topo}/{exchange}/t{threads}"),
                );
                // jitter never touches the wire either
                assert_eq!(r.fabric.bytes_up, jittered.fabric.bytes_up);
                assert_eq!(r.fabric.bytes_down, jittered.fabric.bytes_down);
                assert_eq!(r.fabric.rounds, jittered.fabric.rounds);
            }
        }
    }
}

#[test]
fn staleness_window_deterministic_under_jitter() {
    // K = 2 under jitter: bit-identical across thread counts and repeat
    // runs (the windowed scheduler's determinism contract — gradients
    // depend only on the K-back param version and per-learner state;
    // jitter shapes only the simulated timeline).
    let reference = train_window(Kind::AdaComp, 1, "ring", "streamed", 2, 0.3);
    assert!(!reference.diverged);
    for threads in [1usize, 4] {
        for repeat in 0..2 {
            let r = train_window(Kind::AdaComp, threads, "ring", "streamed", 2, 0.3);
            assert_epochs_bitwise(&reference, &r, &format!("K=2 t{threads} repeat{repeat}"));
            assert_eq!(reference.fabric.bytes_up, r.fabric.bytes_up);
            assert_eq!(reference.fabric.bytes_down, r.fabric.bytes_down);
            assert_eq!(reference.fabric.rounds, r.fabric.rounds);
        }
    }
    // both modes run the same windowed schedule
    let barrier = train_window(Kind::AdaComp, 4, "ring", "barrier", 2, 0.3);
    assert_epochs_bitwise(&reference, &barrier, "K=2 barrier");
    // the window genuinely delays gradients: K = 2 is a different (still
    // converging) trajectory than synchronous
    let sync = train_matrix(Kind::AdaComp, 1, "ring", "streamed");
    assert_ne!(
        reference.epochs[0].train_loss.to_bits(),
        sync.epochs[0].train_loss.to_bits(),
        "K=2 must train on delayed param versions, not θ_t"
    );
    // the run still learns through the delay (AdaComp's residue tolerance)
    assert!(
        reference.epochs.last().unwrap().train_loss
            < reference.epochs.first().unwrap().train_loss
    );
    // stall accounting: every step has a critical learner, and the
    // simulated stall time is finite and non-negative
    let total_crit: u64 = reference.fabric.crit_steps.iter().sum();
    assert_eq!(total_crit, reference.fabric.steps);
    assert!(reference.fabric.stall_s.is_finite() && reference.fabric.stall_s >= 0.0);
}

#[test]
fn window_knobs_validated_by_engine() {
    // satellite: the engine itself is the validation backstop (config and
    // CLI route through the same validate_window)
    let ds = GaussianMixture::new(3, 16, 4, 100, 50, 0.6);
    let exe = NativeMlp::new(&[16, 8, 4], 10);
    let params = exe.init_params(1);
    let layout = exe.layout().clone();
    for (staleness, jitter, needle) in [
        (99usize, 0.0f64, "0 <= K <= 16"),
        (0, 1.0, "0.0 <= jitter < 1.0"),
        (0, -0.3, "0.0 <= jitter < 1.0"),
    ] {
        let mut cfg = base_cfg(Kind::None, 1);
        cfg.epochs = 1;
        cfg.steps_per_epoch = 1;
        cfg.staleness = staleness;
        cfg.link.jitter = jitter;
        let mut engine = Engine::new(&exe, &ds, &layout);
        let err = engine.run(&cfg, &params).unwrap_err().to_string();
        assert!(err.contains(needle), "K={staleness} j={jitter}: {err}");
    }
    // the core-budget knob validates through the same backstop
    let mut cfg = base_cfg(Kind::None, 1);
    cfg.epochs = 1;
    cfg.steps_per_epoch = 1;
    cfg.kernel_threads = 99;
    let mut engine = Engine::new(&exe, &ds, &layout);
    let err = engine.run(&cfg, &params).unwrap_err().to_string();
    assert!(err.contains("0 <= N <= 64"), "{err}");
}

#[test]
fn kernel_threads_bit_identical_across_budgets_and_modes() {
    // acceptance: engine results (losses, test errors, wire bytes) are
    // bit-identical across kernel_threads in {1, 2, 4} and across exchange
    // modes. The model is sized so fc1's forward GEMM (64x128 @ 128x512)
    // crosses gemm::MIN_PAR_FLOPS — the parallel tile grid genuinely runs.
    let ds = GaussianMixture::new(3, 128, 4, 400, 100, 0.6);
    let exe = NativeMlp::new(&[128, 512, 4], 50);
    let params = exe.init_params(11);
    let layout = exe.layout().clone();
    let run = |kernel_threads: usize, exchange: &str| {
        let mut cfg = base_cfg(Kind::AdaComp, 2);
        cfg.epochs = 2;
        cfg.steps_per_epoch = 4;
        cfg.batch_per_learner = 64;
        cfg.threads = 2;
        cfg.exchange = exchange.into();
        cfg.kernel_threads = kernel_threads;
        let mut engine = Engine::new(&exe, &ds, &layout);
        engine.run(&cfg, &params).expect("run")
    };
    let reference = run(1, "streamed");
    assert!(!reference.diverged);
    for exchange in ["streamed", "barrier"] {
        for kt in [1usize, 2, 4] {
            let r = run(kt, exchange);
            assert_epochs_bitwise(
                &reference,
                &r,
                &format!("kernel_threads={kt} exchange={exchange}"),
            );
            assert_eq!(r.fabric.bytes_up, reference.fabric.bytes_up, "{exchange}/{kt}");
            assert_eq!(
                r.fabric.bytes_down, reference.fabric.bytes_down,
                "{exchange}/{kt}"
            );
        }
    }
}

#[test]
fn dense_baseline_mode_and_topology_independent() {
    // satellite: the projected-speedup dense baseline must not vary with
    // the topology or exchange mode. FabricStats::dense_comm_total_s
    // cancels the measured compute, leaving exactly
    // steps × plan.dense_round_s — a deterministic quantity.
    let mut vals: Vec<(String, f64)> = Vec::new();
    for topo in TOPOLOGIES {
        for exchange in ["streamed", "barrier"] {
            let r = train_matrix(Kind::AdaComp, 1, topo, exchange);
            let steps = r.fabric.steps as f64;
            assert!(steps > 0.0);
            vals.push((format!("{topo}/{exchange}"), r.fabric.dense_comm_total_s() / steps));
        }
    }
    let name0 = vals[0].0.clone();
    let v0 = vals[0].1;
    for (name, v) in &vals[1..] {
        assert!(
            (*v - v0).abs() < 1e-12,
            "dense baseline differs: {name0}={v0} vs {name}={v}"
        );
    }
}

#[test]
fn sharded_ps_overlaps_ports_on_timeline() {
    // ps:4 runs the same rounds as ps but pipelines buckets across shard
    // ports: identical bytes and per-round comm, strictly earlier overlap
    // completion whenever two buckets' rounds would have queued on the
    // single port. The comparison cancels the measured compute
    // (FabricStats::comm_tail_s), and a deliberately slow link makes each
    // simulated round (~40ms) dwarf any scheduler-preemption gap between
    // consecutive bucket pack stamps — the strict inequality cannot tie
    // from timing noise.
    let slow = LinkModel {
        latency_s: 5e-3,
        bandwidth_bps: 1.25e9,
        ..LinkModel::default()
    };
    let run = |topo: &str| {
        let ds = GaussianMixture::new(3, 16, 4, 800, 200, 0.6);
        let exe = NativeMlp::new(&[16, 32, 4], 50);
        let params = exe.init_params(11);
        let layout = exe.layout().clone();
        let mut cfg = base_cfg(Kind::AdaComp, 4);
        cfg.epochs = 2;
        cfg.steps_per_epoch = 12;
        cfg.threads = 1;
        cfg.topology = topo.into();
        cfg.link = slow;
        let mut engine = Engine::new(&exe, &ds, &layout);
        engine.run(&cfg, &params).expect("run")
    };
    let flat = run("ps");
    let sharded = run("ps:4");
    assert_eq!(flat.fabric.bytes_up, sharded.fabric.bytes_up);
    assert_eq!(flat.fabric.bytes_down, sharded.fabric.bytes_down);
    assert!((flat.fabric.sim_time_s - sharded.fabric.sim_time_s).abs() < 1e-9);
    assert!(
        sharded.fabric.comm_tail_s() < flat.fabric.comm_tail_s(),
        "ps:4 comm tail {} !< ps comm tail {}",
        sharded.fabric.comm_tail_s(),
        flat.fabric.comm_tail_s()
    );
}

#[test]
fn streamed_overlap_beats_barrier_timeline() {
    // the simulated overlapped step time must be strictly below the
    // serialized model of the same run, and the compressed+overlapped
    // pipeline must project a speedup over dense/barrier
    let s = train_mode(Kind::AdaComp, 4, 4, "streamed");
    assert!(s.fabric.steps > 0);
    assert!(
        s.fabric.sim_overlap_s < s.fabric.sim_barrier_s,
        "overlap {} !< barrier {}",
        s.fabric.sim_overlap_s,
        s.fabric.sim_barrier_s
    );
    // the dense baseline is a coalesced barrier round: on this deliberately
    // tiny latency-bound model the streamed per-layer messages can cost more
    // than coalesced dense, so only finiteness/positivity is structural here
    // (bench_step asserts the real win at benchmark scale)
    assert!(s.fabric.projected_speedup() > 0.0);
    assert!(s.fabric.sim_dense_s > 0.0);
    assert!(s.fabric.sim_step_s() > 0.0);
    // the barrier path records the serialized placement: overlap == barrier
    let b = train_mode(Kind::AdaComp, 4, 4, "barrier");
    assert!((b.fabric.sim_overlap_s - b.fabric.sim_barrier_s).abs() < 1e-12);
}

#[test]
fn unknown_names_error_with_valid_lists() {
    // satellite: a typo'd --topology/--exchange/optimizer must fail with
    // the valid names, not a bare unwrap panic
    let ds = GaussianMixture::new(3, 16, 4, 100, 50, 0.6);
    let exe = NativeMlp::new(&[16, 8, 4], 10);
    let params = exe.init_params(1);
    let layout = exe.layout().clone();
    for (field, needle) in [("topology", "ring"), ("exchange", "streamed"), ("optimizer", "sgd")]
    {
        let mut cfg = base_cfg(Kind::None, 1);
        cfg.epochs = 1;
        cfg.steps_per_epoch = 1;
        match field {
            "topology" => cfg.topology = "bogus".into(),
            "exchange" => cfg.exchange = "bogus".into(),
            _ => cfg.optimizer = "bogus".into(),
        }
        let mut engine = Engine::new(&exe, &ds, &layout);
        let err = engine.run(&cfg, &params).unwrap_err().to_string();
        assert!(
            err.contains("bogus") && err.contains(needle),
            "{field}: {err}"
        );
    }
}

#[test]
fn adam_optimizer_with_compression() {
    let ds = GaussianMixture::new(3, 16, 4, 800, 200, 0.6);
    let exe = NativeMlp::new(&[16, 32, 4], 50);
    let params = exe.init_params(11);
    let layout = exe.layout().clone();
    let mut cfg = base_cfg(Kind::AdaComp, 2);
    cfg.optimizer = "adam".into();
    cfg.lr = LrSchedule::Constant(0.01);
    let mut engine = Engine::new(&exe, &ds, &layout);
    let rec = engine.run(&cfg, &params).expect("run");
    assert!(!rec.diverged);
    assert!(rec.final_test_error() < 20.0, "err {}", rec.final_test_error());
}

#[test]
fn epoch_hook_sees_residues() {
    let ds = GaussianMixture::new(3, 16, 4, 400, 100, 0.6);
    let exe = NativeMlp::new(&[16, 32, 4], 50);
    let params = exe.init_params(1);
    let layout = exe.layout().clone();
    let cfg = base_cfg(Kind::AdaComp, 1);
    let mut engine = Engine::new(&exe, &ds, &layout);
    let mut calls = 0usize;
    let mut hook = |_epoch: usize, comp: &dyn adacomp::Compressor, dw: &[f32]| {
        calls += 1;
        assert_eq!(comp.residue(0).len(), layout.layers[0].len());
        assert!(!dw.is_empty());
    };
    engine
        .run_with_hook(&cfg, &params, Some(&mut hook))
        .expect("run");
    assert_eq!(calls, 6);
}

/// One hermetic char-LSTM engine run (paper Table 2 recurrent scenario):
/// Markov-Shakespeare corpus, embed -> LSTM -> fc, AdaComp at the paper's
/// fc/lstm/embed L_T default of 500.
fn char_lstm_run(threads: usize) -> adacomp::metrics::RunRecord {
    use adacomp::data::shakespeare::Shakespeare;
    use adacomp::runtime::native_lstm::NativeCharLstm;
    let ds = Shakespeare::new(9, 30_000, 16, 320, 80);
    let exe = NativeCharLstm::new(67, 16, &[32], 16).expect("valid dims");
    let params = exe.init_params(21);
    let layout = exe.layout().clone();
    let cfg = TrainConfig {
        run_name: "char-lstm-adacomp".into(),
        model_name: "char_lstm".into(),
        backend: "native".into(),
        n_learners: 2,
        batch_per_learner: 8,
        epochs: 3,
        steps_per_epoch: 25,
        lr: LrSchedule::Constant(3e-3),
        optimizer: "adam".into(),
        momentum: 0.0,
        // AdaComp defaults: lt_fc = 500 covers fc, lstm AND embed kinds
        compression: Config::with_kind(Kind::AdaComp),
        seed: 23,
        threads,
        ..TrainConfig::default()
    };
    let mut engine = Engine::new(&exe, &ds, &layout);
    engine.run(&cfg, &params).expect("run")
}

#[test]
fn char_lstm_engine_with_adacomp_learns() {
    let rec = char_lstm_run(1);
    assert!(!rec.diverged);
    assert_eq!(rec.epochs.len(), 3);
    // loss strictly decreases across epochs on the Markov-Shakespeare LM
    for w in rec.epochs.windows(2) {
        assert!(
            w[1].train_loss < w[0].train_loss,
            "epoch {} loss {} !< epoch {} loss {}",
            w[1].epoch,
            w[1].train_loss,
            w[0].epoch,
            w[0].train_loss
        );
    }
    // recurrent layers actually compress (everything here is the fc bucket:
    // embed + lstm + fc kinds)
    let last = rec.epochs.last().unwrap();
    assert!(last.comp_fc.elements > 0);
    assert!(rec.mean_rate_wire() > 5.0, "rate {}", rec.mean_rate_wire());
}

#[test]
fn char_lstm_parallel_matches_sequential_bitwise() {
    // the determinism contract must hold for the new recurrent backend too
    let seq = char_lstm_run(1);
    let par = char_lstm_run(4);
    assert_eq!(seq.epochs.len(), par.epochs.len());
    for (a, b) in seq.epochs.iter().zip(par.epochs.iter()) {
        assert_eq!(
            a.train_loss.to_bits(),
            b.train_loss.to_bits(),
            "epoch {}: threads=1 loss {} vs threads=4 loss {}",
            a.epoch,
            a.train_loss,
            b.train_loss
        );
        assert_eq!(a.test_error_pct.to_bits(), b.test_error_pct.to_bits());
    }
    assert_eq!(seq.fabric.bytes_up, par.fabric.bytes_up);
    assert_eq!(seq.fabric.bytes_down, par.fabric.bytes_down);
}

/// The elastic-fleet knob matrix: everything `train_window` sweeps plus a
/// churn schedule and an MTBF failure rate (2 epochs x 12 steps, 4 learners).
fn train_churn(
    kind: Kind,
    threads: usize,
    topology: &str,
    exchange: &str,
    staleness: usize,
    churn: &str,
    mtbf: u64,
) -> adacomp::metrics::RunRecord {
    let ds = GaussianMixture::new(3, 16, 4, 800, 200, 0.6);
    let exe = NativeMlp::new(&[16, 32, 4], 50);
    let params = exe.init_params(11);
    let layout = exe.layout().clone();
    let mut cfg = base_cfg(kind, 4);
    cfg.epochs = 2;
    cfg.steps_per_epoch = 12;
    cfg.threads = threads;
    cfg.topology = topology.into();
    cfg.exchange = exchange.into();
    cfg.staleness = staleness;
    cfg.churn = churn.into();
    cfg.mtbf = mtbf;
    let mut engine = Engine::new(&exe, &ds, &layout);
    engine.run(&cfg, &params).expect("run")
}

#[test]
fn churn_deterministic_across_threads_and_modes() {
    // ISSUE 6 acceptance: same seed + churn schedule => bit-identical
    // params (losses, test errors, wire bytes) across 1/4 threads and
    // streamed/barrier — membership epochs drain the window at the same
    // deterministic step boundary everywhere.
    let reference = train_churn(Kind::AdaComp, 1, "ring", "streamed", 0, "fail@12:1", 0);
    assert!(!reference.diverged);
    assert_eq!(reference.fabric.membership.len(), 1);
    for exchange in ["streamed", "barrier"] {
        for threads in [1usize, 4] {
            let r = train_churn(Kind::AdaComp, threads, "ring", exchange, 0, "fail@12:1", 0);
            assert_epochs_bitwise(&reference, &r, &format!("churn {exchange}/t{threads}"));
            assert_eq!(reference.fabric.bytes_up, r.fabric.bytes_up);
            assert_eq!(reference.fabric.bytes_down, r.fabric.bytes_down);
        }
    }
    // a no-churn run diverges from the churned one only AFTER the event
    // step: fail@12 lands exactly on the epoch boundary, so epoch 0 is
    // bit-equal and epoch 1 (3 learners vs 4) is not
    let still = train_churn(Kind::AdaComp, 1, "ring", "streamed", 0, "", 0);
    assert_eq!(
        still.epochs[0].train_loss.to_bits(),
        reference.epochs[0].train_loss.to_bits(),
        "pre-event trajectory must be untouched"
    );
    assert_ne!(
        still.epochs[1].train_loss.to_bits(),
        reference.epochs[1].train_loss.to_bits(),
        "post-event trajectory must reflect the smaller fleet"
    );
    // mid-epoch event under a live staleness window: the drain-to-frontier
    // rule keeps the same determinism contract
    let k2 = train_churn(Kind::AdaComp, 1, "ring", "streamed", 2, "fail@6:1", 0);
    assert!(!k2.diverged);
    for threads in [1usize, 4] {
        let r = train_churn(Kind::AdaComp, threads, "ring", "barrier", 2, "fail@6:1", 0);
        assert_epochs_bitwise(&k2, &r, &format!("churn K=2 t{threads}"));
        assert_eq!(k2.fabric.bytes_up, r.fabric.bytes_up);
    }
    // recovery accounting is populated
    let m = &reference.fabric.membership[0];
    assert_eq!(m.kind, "fail");
    assert_eq!(m.step, 12);
    assert_eq!(m.n_after, 3);
    assert!(m.rebuild_s >= 0.0 && m.rebuild_s.is_finite());
    assert!(m.drain_stall_s >= 0.0 && m.drain_stall_s.is_finite());
    assert!(reference.fabric.drain_stall_s >= 0.0);
}

#[test]
fn leave_hands_over_state_fail_loses_it() {
    // ISSUE 6 tentpole semantics: `leave` rides the v2 checkpoint handover
    // (residual mass folds into the survivors), `fail` loses it, `join`
    // adds cold learners — and the three kinds are distinguishable in the
    // loss trajectory.
    let leave = train_churn(Kind::AdaComp, 4, "ring", "streamed", 0, "leave@12:2", 0);
    let fail = train_churn(Kind::AdaComp, 4, "ring", "streamed", 0, "fail@12:2", 0);
    let join = train_churn(Kind::AdaComp, 4, "ring", "streamed", 0, "join@12:1", 0);
    assert!(!leave.diverged && !fail.diverged && !join.diverged);
    // leave preserves residual L1 mass, fail loses it
    assert!(
        leave.fabric.handover_l1 > 0.0,
        "leave must hand over residual mass, got {}",
        leave.fabric.handover_l1
    );
    assert_eq!(leave.fabric.lost_residual_l1, 0.0);
    assert!(
        fail.fabric.lost_residual_l1 > 0.0,
        "fail must lose residual mass, got {}",
        fail.fabric.lost_residual_l1
    );
    assert_eq!(fail.fabric.handover_l1, 0.0);
    // ...and the same mass is at stake either way (same seed, same step,
    // same departing learners): lost-on-fail == handed-over-on-leave
    assert_eq!(
        fail.fabric.lost_residual_l1.to_bits(),
        leave.fabric.handover_l1.to_bits(),
        "fail {} vs leave {}",
        fail.fabric.lost_residual_l1,
        leave.fabric.handover_l1
    );
    // membership timeline in the run record
    assert_eq!(leave.fabric.membership[0].kind, "leave");
    assert_eq!(leave.fabric.membership[0].n_after, 2);
    assert_eq!(join.fabric.membership[0].kind, "join");
    assert_eq!(join.fabric.membership[0].n_after, 5);
    // all three post-event trajectories differ
    let (l, f, j) = (
        leave.epochs[1].train_loss.to_bits(),
        fail.epochs[1].train_loss.to_bits(),
        join.epochs[1].train_loss.to_bits(),
    );
    assert_ne!(l, f, "leave vs fail");
    assert_ne!(l, j, "leave vs join");
    assert_ne!(f, j, "fail vs join");
}

#[test]
fn leave_converges_better_than_matched_fail() {
    // ISSUE 6 acceptance: a graceful `leave` run reaches a strictly lower
    // final train loss than the matched `fail` run — the handed-over
    // residual gradient mass (error-feedback state) is real signal, and
    // losing 3 of 4 learners' accumulated residues costs convergence.
    let run = |churn: &str| {
        let ds = GaussianMixture::new(3, 16, 4, 800, 200, 0.6);
        let exe = NativeMlp::new(&[16, 32, 4], 50);
        let params = exe.init_params(11);
        let layout = exe.layout().clone();
        let mut cfg = base_cfg(Kind::AdaComp, 4);
        cfg.epochs = 3;
        cfg.steps_per_epoch = 15;
        cfg.churn = churn.into();
        let mut engine = Engine::new(&exe, &ds, &layout);
        engine.run(&cfg, &params).expect("run")
    };
    let leave = run("leave@10:3");
    let fail = run("fail@10:3");
    assert!(!leave.diverged && !fail.diverged);
    let ll = leave.epochs.last().unwrap().train_loss;
    let lf = fail.epochs.last().unwrap().train_loss;
    assert!(
        ll < lf,
        "leave final loss {ll} must be strictly below matched fail {lf}"
    );
}

#[test]
fn mtbf_failures_are_deterministic() {
    // --mtbf draws are precomputed from the run seed: the same rate gives
    // the same failure schedule at every thread count and exchange mode.
    let a = train_churn(Kind::AdaComp, 1, "ring", "streamed", 0, "", 4);
    let b = train_churn(Kind::AdaComp, 4, "ring", "barrier", 0, "", 4);
    assert_epochs_bitwise(&a, &b, "mtbf=4");
    assert_eq!(a.fabric.bytes_up, b.fabric.bytes_up);
    assert_eq!(a.fabric.membership.len(), b.fabric.membership.len());
    for (ma, mb) in a.fabric.membership.iter().zip(b.fabric.membership.iter()) {
        assert_eq!(ma.step, mb.step);
        assert_eq!(ma.n_after, mb.n_after);
        assert_eq!(ma.kind, "fail");
    }
    // the seed-7 draw at mtbf 4 fails a learner at steps 4 and 7 of the
    // 24-step run — the knob must actually fire, not just parse
    assert!(!a.fabric.membership.is_empty(), "mtbf 4 drew no failures in 24 steps");
}

#[test]
fn churn_topology_degrades_and_recovers() {
    // tentpole: on every membership epoch the topology revalidates against
    // the new learner count — ps:4 over 2 learners degrades (logged, not
    // fatal) and a later join restores the requested spec.
    let r = train_churn(Kind::AdaComp, 4, "ps:4", "streamed", 0, "fail@6:2,join@12:2", 0);
    assert!(!r.diverged);
    assert_eq!(r.fabric.membership.len(), 2);
    let down = &r.fabric.membership[0];
    assert_eq!(down.n_after, 2);
    assert!(down.degraded, "ps:4 over 2 learners must degrade");
    assert_eq!(down.topology, "ps:2");
    let up = &r.fabric.membership[1];
    assert_eq!(up.n_after, 4);
    assert!(!up.degraded, "regrown fleet must restore the requested topology");
    assert_eq!(up.topology, "ps:4");
}

/// Executor wrapper that panics inside the Nth streamed grad-ready
/// callback — mid-backward, while the engine's bucket scan is live and
/// sibling workers may be parked in `wait_runnable`.
struct PanicInjector {
    inner: Box<dyn adacomp::runtime::Executor + Send>,
    calls: std::sync::Arc<std::sync::atomic::AtomicUsize>,
    panic_at: usize,
}

impl adacomp::runtime::Executor for PanicInjector {
    fn step(
        &mut self,
        params: &[f32],
        batch: &adacomp::runtime::Batch,
    ) -> anyhow::Result<adacomp::runtime::StepOut> {
        self.inner.step(params, batch)
    }
    fn eval(
        &mut self,
        params: &[f32],
        batch: &adacomp::runtime::Batch,
    ) -> anyhow::Result<adacomp::runtime::EvalOut> {
        self.inner.eval(params, batch)
    }
    fn step_batch_sizes(&self) -> Vec<usize> {
        self.inner.step_batch_sizes()
    }
    fn eval_batch(&self) -> usize {
        self.inner.eval_batch()
    }
    fn streams(&self) -> bool {
        self.inner.streams()
    }
    fn step_streamed(
        &mut self,
        params: &[f32],
        batch: &adacomp::runtime::Batch,
        on_ready: &mut adacomp::runtime::GradReady<'_>,
    ) -> anyhow::Result<adacomp::runtime::StepOut> {
        let call = self
            .calls
            .fetch_add(1, std::sync::atomic::Ordering::SeqCst)
            + 1;
        let blow_up = call == self.panic_at;
        let mut wrapped = |r: std::ops::Range<usize>, g: &[f32]| {
            if blow_up {
                panic!("injected executor fault");
            }
            on_ready(r, g);
        };
        self.inner.step_streamed(params, batch, &mut wrapped)
    }
}

struct PanicFactory {
    inner: NativeMlp,
    calls: std::sync::Arc<std::sync::atomic::AtomicUsize>,
    panic_at: usize,
}

impl adacomp::runtime::ExecutorFactory for PanicFactory {
    fn backend(&self) -> &'static str {
        "native-faulty"
    }
    fn build_worker(&self) -> anyhow::Result<Box<dyn adacomp::runtime::Executor + Send>> {
        Ok(Box::new(PanicInjector {
            inner: self.inner.build_worker()?,
            calls: self.calls.clone(),
            panic_at: self.panic_at,
        }))
    }
    fn build_local(&self) -> anyhow::Result<Box<dyn adacomp::runtime::Executor>> {
        // evaluation and the sequential fallback stay healthy — only the
        // pool workers carry the injected fault
        self.inner.build_local()
    }
}

#[test]
fn worker_panic_mid_stream_surfaces_without_deadlock() {
    // pool.rs hardening satellite: a worker panicking inside the streamed
    // grad-ready callback mid-window must (a) wake every sibling parked in
    // wait_runnable, (b) surface through the engine's Result with the
    // panic payload, and (c) never deadlock the engine's bucket scan or
    // the scope join. The staleness window (K = 2) guarantees parked
    // siblings exist when the fault fires.
    let ds = GaussianMixture::new(3, 16, 4, 800, 200, 0.6);
    let factory = PanicFactory {
        inner: NativeMlp::new(&[16, 32, 4], 50),
        calls: std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0)),
        panic_at: 6,
    };
    let params = factory.inner.init_params(11);
    let layout = factory.inner.layout().clone();
    let mut cfg = base_cfg(Kind::AdaComp, 4);
    cfg.epochs = 1;
    cfg.steps_per_epoch = 10;
    cfg.threads = 4;
    cfg.staleness = 2;
    let mut engine = Engine::new(&factory, &ds, &layout);
    let err = format!("{:#}", engine.run(&cfg, &params).unwrap_err());
    assert!(
        err.contains("learner phase failed"),
        "engine must wrap the worker failure: {err}"
    );
    assert!(
        err.contains("injected executor fault"),
        "panic payload must survive: {err}"
    );
    // both exchange modes drain: the barrier path waits in wait_counter,
    // which polls the failure flag instead of blocking forever
    let factory = PanicFactory {
        inner: NativeMlp::new(&[16, 32, 4], 50),
        calls: std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0)),
        panic_at: 6,
    };
    let mut cfg = base_cfg(Kind::AdaComp, 4);
    cfg.epochs = 1;
    cfg.steps_per_epoch = 10;
    cfg.threads = 4;
    cfg.staleness = 2;
    cfg.exchange = "barrier".into();
    let mut engine = Engine::new(&factory, &ds, &layout);
    let err = format!("{:#}", engine.run(&cfg, &params).unwrap_err());
    assert!(err.contains("injected executor fault"), "{err}");
}

#[test]
fn native_cnn_engine_with_adacomp() {
    // hermetic conv path: tiny CNN + engine + adacomp (conv L_T default 50)
    use adacomp::data::cifar_like::CifarLike;
    use adacomp::runtime::native_cnn::{ConvStage, NativeCnn};
    let ds = CifarLike::cifar10(5, 320, 80);
    let exe = NativeCnn::new(
        32,
        32,
        &[ConvStage { cin: 3, cout: 8 }, ConvStage { cin: 8, cout: 8 }],
        10,
        40,
    )
    .expect("32x32 divides 2 pool stages");
    let params = exe.init_params(3);
    let layout = exe.layout().clone();
    let cfg = TrainConfig {
        run_name: "native-cnn-adacomp".into(),
        model_name: "native_cnn".into(),
        n_learners: 2,
        batch_per_learner: 16,
        epochs: 3,
        steps_per_epoch: 10,
        lr: LrSchedule::Constant(0.02),
        compression: Config::with_kind(Kind::AdaComp),
        ..TrainConfig::default()
    };
    let mut engine = Engine::new(&exe, &ds, &layout);
    let rec = engine.run(&cfg, &params).expect("run");
    assert!(!rec.diverged);
    assert!(rec.epochs.len() == 3);
    // loss must move (training is happening through the conv path)
    assert!(rec.epochs[2].train_loss < rec.epochs[0].train_loss);
    // conv layers compressed at conv-kind rates
    let last = rec.epochs.last().unwrap();
    assert!(last.comp_conv.elements > 0);
    assert!(last.comp_conv.rate_paper() > 10.0);
}

/// Adaptive-control-plane runs: the staleness/jitter matrix plus the
/// controller mode, short epochs so multiple retune boundaries land.
fn train_ctrl(
    threads: usize,
    exchange: &str,
    controller: &str,
    staleness: usize,
    jitter: f64,
    epochs: usize,
) -> adacomp::metrics::RunRecord {
    let ds = GaussianMixture::new(3, 16, 4, 800, 200, 0.6);
    let exe = NativeMlp::new(&[16, 32, 4], 50);
    let params = exe.init_params(11);
    let layout = exe.layout().clone();
    let mut cfg = base_cfg(Kind::AdaComp, 4);
    cfg.epochs = epochs;
    cfg.steps_per_epoch = 12;
    cfg.threads = threads;
    cfg.exchange = exchange.into();
    cfg.staleness = staleness;
    cfg.link.jitter = jitter;
    cfg.controller = controller.into();
    let mut engine = Engine::new(&exe, &ds, &layout);
    engine.run(&cfg, &params).expect("run")
}

#[test]
fn controller_deterministic_across_threads_and_modes() {
    // ISSUE 10 acceptance: with the controller on, the same seed + jitter
    // gives a bit-identical knob trajectory AND final params across
    // {1, 4} threads x {streamed, barrier} — the controller consumes only
    // deterministic projections (seeded jitter draws, serialized wire
    // bytes, plan shape), never wall-clock.
    let reference = train_ctrl(1, "streamed", "on", 2, 0.3, 3);
    assert!(!reference.diverged);
    assert!(
        !reference.fabric.control.is_empty(),
        "jitter 0.3 over a multi-bucket compressed run must trigger retunes"
    );
    assert_eq!(
        reference.fabric.control_retunes as usize,
        reference.fabric.control.len()
    );
    for exchange in ["streamed", "barrier"] {
        for threads in [1usize, 4] {
            let r = train_ctrl(threads, exchange, "on", 2, 0.3, 3);
            assert_epochs_bitwise(&reference, &r, &format!("controller {exchange}/t{threads}"));
            assert_eq!(
                reference.fabric.control, r.fabric.control,
                "decision timeline must be identical ({exchange}/t{threads})"
            );
            assert_eq!(reference.fabric.bytes_up, r.fabric.bytes_up);
            assert_eq!(reference.fabric.bytes_down, r.fabric.bytes_down);
        }
    }
}

#[test]
fn controller_off_is_inert() {
    // the default mode records nothing and matches the static engine
    // (same knobs, same helper path) bit for bit
    let off = train_ctrl(4, "streamed", "off", 2, 0.3, 2);
    assert!(off.fabric.control.is_empty());
    assert_eq!(off.fabric.control_retunes, 0);
    let legacy = train_window(Kind::AdaComp, 4, "ring", "streamed", 2, 0.3);
    assert_epochs_bitwise(&off, &legacy, "controller off vs static engine");
    assert_eq!(off.fabric.bytes_up, legacy.fabric.bytes_up);
}

#[test]
fn controller_on_without_signals_matches_off_bitwise() {
    // every rule holds when there is nothing to react to: jitter 0 (no
    // straggler pressure), K = 0 (nothing to narrow), a dense scheme (no
    // L_T notion), and a single bucket on a single-port ring (no bucket
    // move) — so `on` applies zero decisions and the trajectory is
    // bit-identical to `off`
    let run = |controller: &str| {
        let ds = GaussianMixture::new(3, 16, 4, 800, 200, 0.6);
        let exe = NativeMlp::new(&[16, 32, 4], 50);
        let params = exe.init_params(11);
        let layout = exe.layout().clone();
        let mut cfg = base_cfg(Kind::None, 4);
        cfg.epochs = 2;
        cfg.steps_per_epoch = 12;
        cfg.threads = 4;
        cfg.bucket_bytes = 1_000_000; // whole model in one bucket
        cfg.controller = controller.into();
        let mut engine = Engine::new(&exe, &ds, &layout);
        engine.run(&cfg, &params).expect("run")
    };
    let on = run("on");
    let off = run("off");
    assert!(on.fabric.control.is_empty(), "no signal may fire a rule");
    assert_eq!(on.fabric.control_retunes, 0);
    assert_epochs_bitwise(&on, &off, "controller on-without-signals vs off");
    assert_eq!(on.fabric.bytes_up, off.fabric.bytes_up);
}

#[test]
fn membership_epoch_rederives_auto_bucket_threshold() {
    // ISSUE 10 satellite bugfix: with `--bucket-bytes 0` the coalescing
    // threshold is α·β scaled by the topology's ports — so when a
    // membership event degrades ps:4 (ports 4) to ps:2 (ports 2), the
    // rebuilt plan must use the threshold re-derived for the NEW port
    // count, not the stale pre-churn value.
    use adacomp::comm::ReducePlan;
    let link = LinkModel {
        latency_s: 4.12e-6,
        bandwidth_bps: 1e9, // α·β = 4120 dense wire bytes
        ..Default::default()
    };
    let ds = GaussianMixture::new(3, 16, 4, 800, 200, 0.6);
    let exe = NativeMlp::new(&[16, 32, 4], 50);
    let params = exe.init_params(11);
    let layout = exe.layout().clone();
    let mut cfg = base_cfg(Kind::AdaComp, 4);
    cfg.epochs = 2;
    cfg.steps_per_epoch = 12;
    cfg.threads = 4;
    cfg.topology = "ps:4".into();
    cfg.bucket_bytes = 0; // auto threshold
    cfg.link = link.clone();
    cfg.churn = "fail@12:2".into();
    let mut engine = Engine::new(&exe, &ds, &layout);
    let rec = engine.run(&cfg, &params).expect("run");
    assert!(!rec.diverged);
    assert_eq!(rec.fabric.membership.len(), 1);
    let m = &rec.fabric.membership[0];
    assert!(m.degraded, "ps:4 over 2 learners must degrade");
    assert_eq!(m.topology, "ps:2");
    // the recorded post-churn plan reflects the recomputed threshold …
    let thr2 = ReducePlan::auto_threshold_for(&link, 2);
    assert_eq!(m.threshold_bytes, thr2, "threshold must be re-derived for 2 ports");
    assert_ne!(
        thr2,
        ReducePlan::auto_threshold_for(&link, 4),
        "the pre- and post-churn auto thresholds must actually differ"
    );
    // … and the recorded bucket count is the plan built at that threshold
    let expect = ReducePlan::build(&layout, thr2, 2).num_buckets();
    assert_eq!(m.n_buckets, expect, "plan must be rebuilt at the new threshold");
}
