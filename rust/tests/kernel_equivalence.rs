//! SIMD/scalar bit-equality sweep for the compute kernels (hand-rolled
//! property style, seeded PCG32 — same discipline as tests/property.rs).
//!
//! The contract under test (DESIGN.md §Compute kernels): the AVX2+FMA GEMM
//! microkernel and the AVX2 AdaComp bin kernels must produce *bit-identical*
//! results to their scalar mirrors, because both execute the same packing,
//! tiling, accumulation order and per-lane arithmetic. This is what lets one
//! golden-vector set and one determinism story cover every machine,
//! SIMD or not (`ADACOMP_NO_SIMD=1` reruns this whole file on the scalar
//! path, where the equalities hold trivially).
//!
//!   K1  gemm dispatch == forced scalar, bitwise, over random (m, k, n)
//!       including ragged micro/cache-tile edges, for all three layout
//!       variants (A@B, Aᵀ@B, A@Bᵀ) and accumulate on/off
//!   K2  gemm matches an f64 oracle within accumulation tolerance
//!   K3  adacomp select dispatch == scalar, bitwise, over random residue
//!       states (indices, values, and updated residues)
//!   K4  bin_absmax dispatch == scalar == plain fold, bitwise
//!   K5  parallel gemm == single-threaded gemm, bitwise, over the same
//!       layout x accumulate grid at kernel_threads in {1, 2, 4} — both
//!       microkernels — including shapes big enough to cross the
//!       MIN_PAR_FLOPS gate and actually fan out over the compute pool

use adacomp::compress::select;
use adacomp::tensor::gemm::{self, GemmScratch};
use adacomp::util::rng::Pcg32;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Random shapes biased toward the tiling edges: exact multiples of the
/// MR=6 / NR=16 microkernel and KC=256 / MC=96 cache blocks, plus their
/// off-by-one raggeds, plus fully random small shapes.
fn shapes(rng: &mut Pcg32) -> Vec<(usize, usize, usize)> {
    let mut out = vec![
        (1, 1, 1),
        (6, 256, 16),
        (5, 255, 15),
        (7, 257, 17),
        (12, 256, 32),
        (96, 256, 48),
        (97, 300, 49),
        (130, 520, 19),
        (32, 784, 300),
    ];
    for _ in 0..12 {
        out.push((
            1 + rng.below(100) as usize,
            1 + rng.below(300) as usize,
            1 + rng.below(120) as usize,
        ));
    }
    out
}

#[test]
fn k1_k2_gemm_dispatch_bitwise_equals_scalar_all_layouts() {
    let mut rng = Pcg32::seeded(11);
    for (m, k, n) in shapes(&mut rng) {
        let a = rng.normal_vec(m * k, 1.0); // row-major [m,k]
        let at = transpose(&a, m, k); // [k,m] — Aᵀ storage
        let b = rng.normal_vec(k * n, 1.0); // row-major [k,n]
        let bt = transpose(&b, k, n); // [n,k] — Bᵀ storage
        let c0 = rng.normal_vec(m * n, 1.0);
        let mut s = GemmScratch::default();

        for accumulate in [false, true] {
            // A@B
            let mut cd = c0.clone();
            gemm::matmul(&mut s, &a, &b, &mut cd, m, k, n, accumulate);
            let mut cs = c0.clone();
            gemm::gemm_with(true, &mut s, &a, k, 1, &b, n, 1, &mut cs, m, k, n, accumulate);
            assert_eq!(bits(&cd), bits(&cs), "A@B {m}x{k}x{n} acc={accumulate}");
            oracle_check(&a, &b, &c0, &cd, m, k, n, accumulate);

            // Aᵀ@B (A stored [k,m])
            let mut cd = c0.clone();
            gemm::matmul_at_b(&mut s, &at, &b, &mut cd, m, k, n, accumulate);
            let mut cs = c0.clone();
            gemm::gemm_with(true, &mut s, &at, 1, m, &b, n, 1, &mut cs, m, k, n, accumulate);
            assert_eq!(bits(&cd), bits(&cs), "At@B {m}x{k}x{n} acc={accumulate}");
            oracle_check(&a, &b, &c0, &cd, m, k, n, accumulate);
        }

        // A@Bᵀ (B stored [n,k]; overwrite-only by design)
        let mut cd = c0.clone();
        gemm::matmul_a_bt(&mut s, &a, &bt, &mut cd, m, k, n);
        let mut cs = c0.clone();
        gemm::gemm_with(true, &mut s, &a, k, 1, &bt, 1, k, &mut cs, m, k, n, false);
        assert_eq!(bits(&cd), bits(&cs), "A@Bt {m}x{k}x{n}");
        oracle_check(&a, &b, &c0, &cd, m, k, n, false);
    }
}

fn transpose(x: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut t = vec![0.0f32; x.len()];
    for r in 0..rows {
        for c in 0..cols {
            t[c * rows + r] = x[r * cols + c];
        }
    }
    t
}

/// K2: compare against an f64 accumulation of the same product.
#[allow(clippy::too_many_arguments)]
fn oracle_check(
    a: &[f32],
    b: &[f32],
    c0: &[f32],
    got: &[f32],
    m: usize,
    k: usize,
    n: usize,
    accumulate: bool,
) {
    for i in 0..m {
        for j in 0..n {
            let mut acc = if accumulate { c0[i * n + j] as f64 } else { 0.0 };
            for p in 0..k {
                acc += a[i * k + p] as f64 * b[p * n + j] as f64;
            }
            let g = got[i * n + j] as f64;
            let tol = 1e-4 * acc.abs().max(1.0);
            assert!(
                (g - acc).abs() <= tol,
                "oracle {m}x{k}x{n}[{i},{j}]: got {g}, want {acc}"
            );
        }
    }
}

#[test]
fn k5_parallel_gemm_bitwise_equals_single_thread_all_layouts() {
    let mut rng = Pcg32::seeded(47);
    // the tile-edge shapes from `shapes()` (all below the MIN_PAR_FLOPS
    // gate — they pin the gate itself) plus shapes that genuinely cross it:
    // multi-MC x multi-NR-panel grids with ragged edges
    let mut all = shapes(&mut rng);
    all.extend([(192, 512, 128), (193, 513, 129), (96, 700, 64), (100, 640, 33)]);
    for (m, k, n) in all {
        let a = rng.normal_vec(m * k, 1.0); // row-major [m,k]
        let at = transpose(&a, m, k); // [k,m] — Aᵀ storage
        let b = rng.normal_vec(k * n, 1.0); // row-major [k,n]
        let bt = transpose(&b, k, n); // [n,k] — Bᵀ storage
        let c0 = rng.normal_vec(m * n, 1.0);
        let mut s = GemmScratch::default();

        for force_scalar in [false, true] {
            for accumulate in [false, true] {
                // layouts: (rs_a, cs_a, rs_b, cs_b) for A@B, Aᵀ@B, A@Bᵀ
                for (tag, av, bv, strides) in [
                    ("A@B", &a, &b, (k, 1, n, 1)),
                    ("At@B", &at, &b, (1, m, n, 1)),
                    ("A@Bt", &a, &bt, (k, 1, 1usize, k)),
                ] {
                    let (rs_a, cs_a, rs_b, cs_b) = strides;
                    let mut c1 = c0.clone();
                    gemm::gemm_with_threads(
                        force_scalar, 1, &mut s, av, rs_a, cs_a, bv, rs_b, cs_b, &mut c1,
                        m, k, n, accumulate,
                    );
                    for threads in [2usize, 4] {
                        let mut ct = c0.clone();
                        gemm::gemm_with_threads(
                            force_scalar, threads, &mut s, av, rs_a, cs_a, bv, rs_b, cs_b,
                            &mut ct, m, k, n, accumulate,
                        );
                        assert_eq!(
                            bits(&c1),
                            bits(&ct),
                            "{tag} {m}x{k}x{n} acc={accumulate} \
                             scalar={force_scalar} threads={threads}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn k3_select_dispatch_bitwise_equals_scalar_random_states() {
    let mut rng = Pcg32::seeded(23);
    for trial in 0..200 {
        let n = 1 + rng.below(400) as usize;
        let r0 = rng.normal_vec(n, 1.0);
        let db = rng.normal_vec(n, 0.7);
        // gm drawn from the data so hit rates range from dense to empty
        let gm = select::bin_absmax(&r0) * (0.2 + 0.2 * rng.below(8) as f32);
        if gm <= 0.0 {
            continue;
        }
        let (q, c1) = (0.5, 1.0);
        let base = rng.below(1 << 20);

        let mut rd = r0.clone();
        let (mut id, mut vd) = (Vec::new(), Vec::new());
        select::select_bin_into(&mut rd, &db, gm, q, c1, base, &mut id, &mut vd);

        let mut rs = r0.clone();
        let (mut is_, mut vs) = (Vec::new(), Vec::new());
        select::select_bin_scalar_into(&mut rs, &db, gm, q, c1, base, &mut is_, &mut vs);

        assert_eq!(id, is_, "trial {trial} n={n}: indices");
        assert_eq!(bits(&vd), bits(&vs), "trial {trial} n={n}: values");
        assert_eq!(bits(&rd), bits(&rs), "trial {trial} n={n}: residues");
        // indices strictly ascending — the wire encoder's delta precondition
        assert!(id.windows(2).all(|w| w[0] < w[1]), "trial {trial}: order");
    }
}

#[test]
fn k4_absmax_dispatch_bitwise_equals_scalar_and_fold() {
    let mut rng = Pcg32::seeded(31);
    for n in [0usize, 1, 5, 7, 8, 9, 15, 16, 17, 63, 64, 100, 1000] {
        let v = rng.normal_vec(n, 2.0);
        let fold = v.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        assert_eq!(select::bin_absmax(&v).to_bits(), fold.to_bits(), "n={n}");
        assert_eq!(select::bin_absmax_scalar(&v).to_bits(), fold.to_bits(), "n={n}");
    }
}
