"""AOT pipeline tests: HLO export, init bins, manifest, golden vectors."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M
from compile.kernels import ref


@pytest.fixture(scope="module")
def outdir(tmp_path_factory):
    d = tmp_path_factory.mktemp("artifacts")
    return str(d)


def test_export_model_roundtrip(outdir):
    spec = M.build("mnist_dnn")
    entry = aot.export_model(spec, outdir)
    # init bin holds exactly num_params little-endian f32
    raw = np.fromfile(os.path.join(outdir, entry["init_bin"]), dtype="<f4")
    assert raw.size == entry["num_params"]
    flat = np.concatenate([p.value.reshape(-1) for p in spec.params])
    np.testing.assert_array_equal(raw, flat.astype(np.float32))
    # HLO text parses as an ENTRY computation with the right arity
    hlo = open(os.path.join(outdir, entry["step_hlo"])).read()
    assert "ENTRY" in hlo
    # param tensors + x + y parameters appear
    assert hlo.count("parameter(") >= len(spec.params) + 2
    # manifest entry is self-consistent
    assert entry["x_shape"][0] == spec.batch
    assert [tuple(p["shape"]) for p in entry["params"]] == [
        p.value.shape for p in spec.params
    ]


def test_export_golden_matches_ref(outdir):
    aot.export_golden(outdir)
    data = json.load(open(os.path.join(outdir, "golden_adacomp.json")))
    assert len(data["cases"]) >= 5
    for case in data["cases"]:
        g = jnp.asarray(np.array(case["g"], np.float32))
        h = jnp.asarray(np.array(case["h"], np.float32))
        gq, residue, mask, gmax, scale = ref.adacomp_compress(g, h, case["lt"])
        np.testing.assert_allclose(np.asarray(gq), case["gq"], rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(np.asarray(residue), case["residue"], rtol=1e-6, atol=1e-7)
        assert [int(v) for v in np.asarray(mask)] == case["mask"]
        np.testing.assert_allclose(float(scale), case["scale"], rtol=1e-6)


def test_adacomp_graph_export_executes(outdir):
    """The standalone L1 HLO graph must execute (via jax) and match ref."""

    n, lt = 300, 50

    def compress(g, h):
        from compile.kernels import adacomp as K

        gq, residue, _, _, scale = K.adacomp_compress(g, h, lt)
        return (gq, residue, scale)

    rng = np.random.default_rng(5)
    g = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    h = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    got = jax.jit(compress)(g, h)
    want = ref.adacomp_compress(g, h, lt)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]), rtol=1e-6)
    np.testing.assert_allclose(float(got[2]), float(want[4]), rtol=1e-6)


def test_manifest_default_set():
    assert set(M.DEFAULT_EXPORT) <= set(M.BUILDERS)
    # e2e driver + at least one model per paper family in the default set
    assert "transformer" in M.DEFAULT_EXPORT
    assert "cifar_cnn" in M.DEFAULT_EXPORT  # CNN
    assert "bn50_dnn_s" in M.DEFAULT_EXPORT  # DNN
    assert "char_lstm" in M.DEFAULT_EXPORT  # RNN
