"""Tests for the AOT batch-variant export plan."""

from compile import aot, model as M


def test_variants_include_default_and_one():
    for name in M.DEFAULT_EXPORT:
        spec = M.build(name)
        v = aot.batch_variants(spec)
        assert spec.batch in v
        assert 1 in v
        assert v == sorted(set(v))


def test_cifar_extends_to_2048():
    spec = M.build("cifar_cnn")
    v = aot.batch_variants(spec)
    for b in (256, 512, 1024, 2048):
        assert b in v


def test_halvings_cover_learner_splits():
    # strong scaling: batch/2^k must exist down to 1 so N=2^k learners work
    spec = M.build("cifar_cnn")
    v = set(aot.batch_variants(spec))
    b = spec.batch
    while b >= 1:
        assert b in v
        b //= 2
