"""L1 correctness: Pallas AdaComp kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps layer lengths, bin sizes, dtypes and input scales; the
fixed tests pin the algebraic invariants of Algorithm 2.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import adacomp as K
from compile.kernels import ref


def make_inputs(n, scale=1.0, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    g = (rng.standard_normal(n) * scale).astype(dtype)
    dw = (rng.standard_normal(n) * scale * 0.3).astype(dtype)
    return jnp.asarray(g), jnp.asarray(g + dw)


def assert_same(r, p):
    names = ["gq", "residue", "mask", "gmax", "scale"]
    for a, b, name in zip(r, p, names):
        np.testing.assert_allclose(
            np.asarray(a, np.float32),
            np.asarray(b, np.float32),
            rtol=1e-6,
            atol=1e-7,
            err_msg=name,
        )


# ---------------------------------------------------------------------------
# Pallas vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "n,lt",
    [(50, 50), (49, 50), (1000, 50), (1037, 50), (10240, 500), (300, 7), (1, 1), (5, 500)],
)
def test_pallas_matches_ref(n, lt):
    g, h = make_inputs(n, seed=n * 31 + lt)
    assert_same(ref.adacomp_compress(g, h, lt), K.adacomp_compress(g, h, lt))


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 4096),
    lt=st.integers(1, 600),
    scale=st.sampled_from([1e-4, 1e-2, 1.0, 100.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_matches_ref_hypothesis(n, lt, scale, seed):
    g, h = make_inputs(n, scale=scale, seed=seed)
    assert_same(ref.adacomp_compress(g, h, lt), K.adacomp_compress(g, h, lt))


@settings(max_examples=10, deadline=None)
@given(n=st.integers(8, 1024), lt=st.integers(2, 64), seed=st.integers(0, 1000))
def test_pallas_bf16(n, lt, seed):
    g32, h32 = make_inputs(n, seed=seed)
    g, h = g32.astype(jnp.bfloat16), h32.astype(jnp.bfloat16)
    r = ref.adacomp_compress(g, h, lt)
    p = K.adacomp_compress(g, h, lt)
    for a, b in zip(r, p):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-2, atol=1e-2
        )


@pytest.mark.parametrize("block_bins", [1, 2, 8, 32])
def test_block_size_invariance(block_bins):
    g, h = make_inputs(50 * 32, seed=3)
    base = K.adacomp_compress(g, h, 50, block_bins=8)
    other = K.adacomp_compress(g, h, 50, block_bins=block_bins)
    assert_same(base, other)


# ---------------------------------------------------------------------------
# Algorithm 2 invariants (on the oracle; pallas equality extends them)
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(n=st.integers(2, 2048), lt=st.integers(1, 512), seed=st.integers(0, 10**6))
def test_invariants(n, lt, seed):
    g, h = make_inputs(n, seed=seed)
    gq, residue, mask, gmax, scale = ref.adacomp_compress(g, h, lt)
    gq, residue, mask = np.asarray(gq), np.asarray(residue), np.asarray(mask)
    gnp, hnp = np.asarray(g), np.asarray(h)

    # Conservation: what is not sent stays in the residue.
    np.testing.assert_allclose(gq + residue, gnp, rtol=1e-6, atol=1e-7)
    # Sent values are exactly ternary: 0 or +/- scale.
    sent = gq[mask]
    if sent.size:
        np.testing.assert_allclose(np.abs(sent), float(scale), rtol=1e-6)
    assert np.all(gq[~mask] == 0.0)
    # Selection predicate holds bin-wise.
    nbins = -(-n // lt)
    for b in range(nbins):
        lo, hi = b * lt, min((b + 1) * lt, n)
        gm = np.max(np.abs(gnp[lo:hi]))
        want = (np.abs(hnp[lo:hi]) >= gm) & (gm > 0)
        np.testing.assert_array_equal(mask[lo:hi], want)
    # Scale is the mean of per-bin maxima.
    gmax_np = np.asarray(gmax)
    assert gmax_np.shape == (nbins,)
    np.testing.assert_allclose(float(scale), np.mean(np.abs(gmax_np)), rtol=1e-6)


def test_zero_bin_sends_nothing():
    g = jnp.zeros((100,), jnp.float32)
    h = jnp.zeros((100,), jnp.float32)
    gq, residue, mask, gmax, scale = ref.adacomp_compress(g, h, 10)
    assert int(np.sum(np.asarray(mask))) == 0
    assert float(scale) == 0.0
    p = K.adacomp_compress(g, h, 10)
    assert int(np.sum(np.asarray(p[2]))) == 0


def test_bin_max_not_always_sent():
    """The paper tests |H| >= gmax(G): a max of G whose dW opposes it can be skipped."""
    g = jnp.asarray(np.array([10.0, 1.0, 1.0, 1.0], np.float32))
    dw = jnp.asarray(np.array([-6.0, 0.0, 0.0, 0.0], np.float32))
    h = g + dw  # |h[0]| = 4 < gmax = 10
    _, _, mask, _, _ = ref.adacomp_compress(g, h, 4)
    assert not bool(mask[0])
    assert int(np.sum(np.asarray(mask))) == 0  # nothing clears the max


def test_self_adjusting_selection_counts():
    """The soft threshold adapts: large dW relative to the residue (early
    training) sends many elements; small dW (late training) sends few."""
    n, lt = 5000, 50
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    dw_large = jnp.asarray((2.0 * rng.standard_normal(n)).astype(np.float32))
    dw_small = jnp.asarray((0.001 * rng.standard_normal(n)).astype(np.float32))
    sel_early = int(np.sum(np.asarray(ref.select_mask(g, g + dw_large, lt))))
    sel_late = int(np.sum(np.asarray(ref.select_mask(g, g + dw_small, lt))))
    assert sel_early > 5 * sel_late
    # late-training selection degenerates to roughly the bin maxima
    assert sel_late <= 2 * (n // lt)
