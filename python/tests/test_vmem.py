"""Tests for the TPU resource estimator (compile.vmem)."""

from compile import vmem


def test_tiles_fit_vmem_at_paper_lt():
    for lt in (50, 500):
        r = vmem.kernel_report(1_048_576, lt, 8)
        assert r["vmem_fits"]
        assert r["vmem_utilization"] < 0.01  # huge headroom


def test_memory_bound_regime():
    r = vmem.kernel_report(16_777_216, 500, 8)
    assert r["bound"] == "HBM-bandwidth"
    # 8 f32 accesses/element of HBM traffic (2 passes + fused epilogue)
    assert 30.0 <= r["hbm_bytes"] / r["n"] <= 34.0


def test_roofline_scales_linearly():
    a = vmem.kernel_report(1_000_000, 50, 8)
    b = vmem.kernel_report(2_000_000, 50, 8)
    assert 1.8 < b["roofline_us"] / a["roofline_us"] < 2.2


def test_bins_cover_layer():
    r = vmem.kernel_report(1037, 50, 8)
    assert r["nbins"] == 21
