"""L2 correctness: model zoo shapes, gradient sanity, trainability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

FAST = ["mnist_dnn", "mnist_cnn", "cifar_cnn", "bn50_dnn_s", "char_lstm", "transformer"]
ALL = list(M.BUILDERS)


def make_batch(spec, seed=0, batch=None):
    rng = np.random.default_rng(seed)
    b = batch or spec.batch
    if spec.x_dtype == "f32":
        x = rng.standard_normal((b, *spec.x_shape)).astype(np.float32)
    else:
        x = rng.integers(0, spec.num_classes, (b, *spec.x_shape)).astype(np.int32)
    yshape = (b,) if spec.y_ndim == 1 else (b, spec.seq_len)
    y = rng.integers(0, spec.num_classes, yshape).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(y)


@pytest.mark.parametrize("name", ALL)
def test_forward_shapes(name):
    spec = M.build(name)
    x, y = make_batch(spec, batch=2 if spec.x_dtype == "f32" else None)
    params = spec.init_values()
    logits = spec.forward(params, x)
    assert logits.shape[-1] == spec.num_classes
    assert logits.shape[0] == x.shape[0]
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", FAST)
def test_step_grad_shapes(name):
    spec = M.build(name)
    x, y = make_batch(spec)
    params = spec.init_values()
    out = spec.step(params, x, y)
    loss, grads = out[0], out[1:]
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    assert len(grads) == len(params)
    for g, p in zip(grads, params):
        assert g.shape == p.shape
        assert bool(jnp.all(jnp.isfinite(g)))


@pytest.mark.parametrize("name", ["mnist_dnn", "cifar_cnn", "char_lstm", "transformer"])
def test_loss_decreases_with_sgd(name):
    """A few full-batch SGD steps on one batch must reduce the loss."""
    spec = M.build(name)
    x, y = make_batch(spec, seed=1)
    params = spec.init_values()
    step = jax.jit(lambda *a: spec.step(list(a[: len(params)]), a[-2], a[-1]))
    lr = {"char_lstm": 1.0, "transformer": 0.1}.get(name, 0.05)
    losses = []
    for _ in range(8):
        out = step(*params, x, y)
        losses.append(float(out[0]))
        params = [p - lr * g for p, g in zip(params, out[1:])]
    assert losses[-1] < losses[0] * 0.98, losses


@pytest.mark.parametrize("name", ["mnist_dnn", "cifar_cnn"])
def test_numerical_gradient(name):
    """Spot-check analytic grads against central differences."""
    spec = M.build(name)
    x, y = make_batch(spec, seed=2, batch=4)
    params = spec.init_values()
    out = spec.step(params, x, y)
    grads = out[1:]
    # check 5 random coordinates of the first weight tensor
    rng = np.random.default_rng(0)
    w = np.asarray(params[0])
    eps = 1e-3
    for _ in range(5):
        idx = tuple(rng.integers(0, s) for s in w.shape)
        wp, wm = w.copy(), w.copy()
        wp[idx] += eps
        wm[idx] -= eps
        lp = float(spec.loss_fn([jnp.asarray(wp)] + params[1:], x, y))
        lm = float(spec.loss_fn([jnp.asarray(wm)] + params[1:], x, y))
        num = (lp - lm) / (2 * eps)
        ana = float(np.asarray(grads[0])[idx])
        assert abs(num - ana) < 5e-2 * max(1.0, abs(num)), (idx, num, ana)


@pytest.mark.parametrize("name", FAST)
def test_evaluate(name):
    spec = M.build(name)
    x, y = make_batch(spec, seed=3)
    params = spec.init_values()
    loss, ncorr = spec.evaluate(params, x, y)
    total = y.size
    assert 0 <= float(ncorr) <= total
    assert np.isfinite(float(loss))


def test_param_kinds_and_lt():
    """Layer-kind tagging drives the paper's L_T defaults (conv 50, fc/lstm 500)."""
    spec = M.build("cifar_cnn")
    kinds = {p.name: p.kind for p in spec.params}
    assert kinds["conv1_w"] == "conv" and kinds["fc_w"] == "fc"
    assert M.LT_DEFAULT["conv"] == 50 and M.LT_DEFAULT["fc"] == 500
    for p in spec.params:
        assert p.lt == M.LT_DEFAULT[p.kind]


def test_char_lstm_paper_shapes():
    spec = M.build("char_lstm")
    by = {p.name: p.value.shape for p in spec.params}
    assert by["lstm1_wx"] == (67, 2048) and by["lstm1_wh"] == (512, 2048)
    assert by["fc_w"] == (512, 67)


def test_bn50_paper_shapes():
    spec = M.build("bn50_dnn")
    by = {p.name: p.value.shape for p in spec.params}
    assert by["fc1_w"] == (440, 1024) and by["fc6_w"] == (1024, 5999)
    assert spec.num_classes == 5999


def test_deterministic_init():
    a = M.build("cifar_cnn", seed=7)
    b = M.build("cifar_cnn", seed=7)
    for pa, pb in zip(a.params, b.params):
        np.testing.assert_array_equal(pa.value, pb.value)
