"""L1 Pallas kernels + pure-jnp oracle for the AdaComp compression step."""

from . import adacomp, ref  # noqa: F401
