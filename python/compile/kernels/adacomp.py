"""L1 — Pallas kernels for the AdaComp compression hot-spot.

The paper's computational argument is that compression must be *localized*
(no global sort) and accelerator friendly. On TPU this maps to: lay the
layer's flat residue out as a ``(num_bins, L_T)`` tile, reduce |G| along the
lane (L_T) dimension inside VMEM for ``g_max``, then do one element-wise VPU
pass for the soft-threshold compare + ternarize. One HBM->VMEM round trip,
zero cross-bin traffic. See DESIGN.md §Hardware-Adaptation.

Two kernels:
  * ``binmax``   — per-bin max of |G|         (reduction, grid over bin rows)
  * ``select``   — soft-threshold send mask   (elementwise, grid over bin rows)

``adacomp_compress`` stitches them with the (tiny) global scale reduction in
plain jnp; XLA fuses the ternarize/residue arithmetic around the kernels.
Everything uses ``interpret=True`` so the lowering is plain HLO that the
rust CPU PJRT client can execute (real-TPU Mosaic lowering is compile-only
in this image; see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Rows of bins processed per grid step. 8 is the TPU sublane width for f32;
# on the interpret path it only affects trace size, not numerics.
DEFAULT_BLOCK_BINS = 8


def _binmax_kernel(g_ref, out_ref):
    """out[b] = max_j |g[b, j]| for each bin row b in the block."""
    out_ref[...] = jnp.max(jnp.abs(g_ref[...]), axis=1)


def _select_kernel(g_ref, h_ref, gmax_ref, mask_ref):
    """mask[b, j] = (|h[b, j]| >= gmax[b]) & (gmax[b] > 0), as 0/1 f32-dtype."""
    gmax = gmax_ref[...][:, None]
    sel = (jnp.abs(h_ref[...]) >= gmax) & (gmax > 0)
    mask_ref[...] = sel.astype(mask_ref.dtype)


def _pick_block(nbins: int, want: int) -> int:
    """Largest divisor of nbins that is <= want (grid must tile exactly)."""
    bb = min(want, nbins)
    while nbins % bb:
        bb -= 1
    return bb


def bin_max(g2: jnp.ndarray, *, block_bins: int = DEFAULT_BLOCK_BINS) -> jnp.ndarray:
    """Per-bin max |G| via Pallas. ``g2`` is (nbins, L_T); returns (nbins,)."""
    nbins, lt = g2.shape
    bb = _pick_block(nbins, block_bins)
    return pl.pallas_call(
        _binmax_kernel,
        grid=(nbins // bb,),
        in_specs=[pl.BlockSpec((bb, lt), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nbins,), g2.dtype),
        interpret=True,
    )(g2)


def select_mask(
    g2: jnp.ndarray,
    h2: jnp.ndarray,
    gmax: jnp.ndarray,
    *,
    block_bins: int = DEFAULT_BLOCK_BINS,
) -> jnp.ndarray:
    """Soft-threshold send mask via Pallas. Returns (nbins, L_T) in g2.dtype (0/1)."""
    nbins, lt = g2.shape
    bb = _pick_block(nbins, block_bins)
    return pl.pallas_call(
        _select_kernel,
        grid=(nbins // bb,),
        in_specs=[
            pl.BlockSpec((bb, lt), lambda i: (i, 0)),
            pl.BlockSpec((bb, lt), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bb, lt), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nbins, lt), g2.dtype),
        interpret=True,
    )(g2, h2, gmax)


@functools.partial(jax.jit, static_argnames=("lt", "block_bins"))
def adacomp_compress(
    g: jnp.ndarray,
    h: jnp.ndarray,
    lt: int,
    *,
    block_bins: int = DEFAULT_BLOCK_BINS,
):
    """Full AdaComp step on one flat layer — Pallas edition of ``ref.adacomp_compress``.

    Returns (gq, residue, mask, gmax, scale); see ref.py for semantics.
    """
    n = g.shape[0]
    g2 = ref.pad_to_bins(g, lt)
    h2 = ref.pad_to_bins(h, lt)
    gmax = bin_max(g2, block_bins=block_bins)
    scale = jnp.mean(jnp.abs(gmax))
    mask2 = select_mask(g2, h2, gmax, block_bins=block_bins)
    mask = mask2.reshape(-1)[:n]
    gq = mask * jnp.sign(g) * scale
    residue = g - gq
    return gq, residue, mask.astype(bool), gmax, scale
