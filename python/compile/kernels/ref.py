"""Pure-jnp oracle for the AdaComp compression step (paper Algorithm 2).

This module is the *correctness ground truth* for
  - the Pallas kernels in ``kernels/adacomp.py`` (pytest compares them), and
  - the rust hot-path implementation in ``rust/src/compress/adacomp.rs``
    (cross-checked through golden vectors emitted by ``aot.py --golden``).

Semantics (paper, Algorithm 2, with our one documented deviation):

  G      = residue + dW
  H      = G + dW                      # soft threshold: residue + 2*dW
  bins   : G split into bins of length L_T (last bin zero-padded)
  gmax_i = max_j |G| over bin i
  scale  = mean_i |gmax_i|             # one scale per layer
  sent   = { j : |H_j| >= gmax(bin(j)) and gmax(bin(j)) > 0 }
  Gq_j   = sign(G_j) * scale           for j in sent, else 0
  residue'_j = G_j - Gq_j

Deviation: the ``gmax > 0`` conjunct. The paper's literal predicate
``|H| >= gmax`` selects *every* element of an all-zero bin (0 >= 0); the
transmitted values would all be zero, inflating traffic with no information.
All three implementations (ref / pallas / rust) share this guard so they stay
bit-identical.

Note the paper compares |H| against the max of |G| (not of |H|): an element
that *was* the bin max of G may fail the test if the latest dW opposes its
residue. Bins may therefore send zero elements. This is intentional.
"""

from __future__ import annotations

import jax.numpy as jnp


def pad_to_bins(g: jnp.ndarray, lt: int) -> jnp.ndarray:
    """Zero-pad flat ``g`` to a multiple of ``lt`` and reshape to (bins, lt)."""
    n = g.shape[0]
    nbins = -(-n // lt)  # ceil div
    pad = nbins * lt - n
    if pad:
        g = jnp.concatenate([g, jnp.zeros((pad,), dtype=g.dtype)])
    return g.reshape(nbins, lt)


def bin_max(g: jnp.ndarray, lt: int) -> jnp.ndarray:
    """Per-bin max of |G|. ``g`` flat; returns (nbins,)."""
    return jnp.max(jnp.abs(pad_to_bins(g, lt)), axis=1)


def layer_scale(gmax: jnp.ndarray) -> jnp.ndarray:
    """Single quantization scale for the layer: mean of the |gmax| vector."""
    return jnp.mean(jnp.abs(gmax))


def select_mask(g: jnp.ndarray, h: jnp.ndarray, lt: int) -> jnp.ndarray:
    """Boolean send-mask, flat, same length as ``g`` (padding stripped)."""
    n = g.shape[0]
    g2 = pad_to_bins(g, lt)
    h2 = pad_to_bins(h, lt)
    gmax = jnp.max(jnp.abs(g2), axis=1, keepdims=True)
    mask = (jnp.abs(h2) >= gmax) & (gmax > 0)
    return mask.reshape(-1)[:n]


def adacomp_compress(g: jnp.ndarray, h: jnp.ndarray, lt: int):
    """Full AdaComp compression step on one layer.

    Args:
      g: flat residue + dW            (what gets quantized / carried over)
      h: flat residue + 2*dW          (what the soft threshold tests)
      lt: bin length L_T (the paper's only new hyper-parameter)

    Returns:
      gq:      flat ternarized sent values (0 where not sent)
      residue: flat new residual gradient  (g - gq)
      mask:    flat bool send-mask
      gmax:    (nbins,) per-bin max |G|
      scale:   scalar layer quantization scale
    """
    n = g.shape[0]
    gmax = bin_max(g, lt)
    scale = layer_scale(gmax)
    mask = select_mask(g, h, lt)
    gq = jnp.where(mask, jnp.sign(g) * scale, jnp.zeros_like(g))
    residue = g - gq
    return gq[:n], residue[:n], mask, gmax, scale
