"""L2 — the model zoo used by the paper's evaluation, in JAX.

Each entry mirrors a row of the paper's Table 1 (scaled where the paper's
dataset is a hardware gate — see DESIGN.md §Substitutions):

  mnist_cnn    2 conv (5x5) + 2 FC + 10-softmax           (paper MNIST-CNN)
  mnist_dnn    784-300-100-10 MLP                         (paper MNIST-DNN, "not shown")
  cifar_cnn    3 conv (5x5) + 1 FC + 10-softmax, ~90k par (paper CIFAR10-CNN, Caffe-like)
  alexnet_s    5 conv + 3 FC, 100-way                     (scaled AlexNet surrogate)
  resnet18_s   8 residual blocks, 16 conv + FC, 100-way   (scaled ResNet18 surrogate)
  resnet50_s   bottleneck residual blocks + FC, 100-way   (scaled ResNet50 surrogate)
  bn50_dnn     440-1024x4-5999 6-layer DNN                (paper BN50-DNN, exact shapes)
  bn50_dnn_s   440-512x4-1500 scaled variant              (fast default for harnesses)
  char_lstm    2 LSTM (67-512, 512-512) + FC 512-67       (paper Shakespeare LSTM, exact)
  transformer  4-layer causal char transformer, d=256     (e2e driver; not in paper)

A ``ModelSpec`` carries the numpy initial parameters (written to
``artifacts/<name>.init.bin``), per-parameter layer kinds (conv / fc / lstm /
embed -> default L_T 50 / 500 / 500 / 500 per the paper), and pure functions

    forward(params, x)        -> logits
    step(params, x, y)        -> (loss, grads)     [AOT-exported]
    evaluate(params, x, y)    -> (loss, ncorrect)  [AOT-exported]
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L

VOCAB = 67  # paper's Shakespeare char vocabulary size

LT_DEFAULT = {"conv": 50, "fc": 500, "lstm": 500, "embed": 500}


@dataclasses.dataclass
class Param:
    name: str
    value: np.ndarray
    kind: str  # conv | fc | lstm | embed

    @property
    def lt(self) -> int:
        return LT_DEFAULT[self.kind]


@dataclasses.dataclass
class ModelSpec:
    name: str
    params: List[Param]
    forward: Callable  # (list_of_arrays, x) -> logits
    x_shape: Tuple[int, ...]  # without batch dim
    x_dtype: str  # "f32" | "i32"
    y_ndim: int  # 1 for image classif (B,), 2 for LM (B,T)
    num_classes: int
    batch: int
    seq_len: int = 0  # LM only

    def init_values(self) -> List[jnp.ndarray]:
        return [jnp.asarray(p.value) for p in self.params]

    # -- exported functions -------------------------------------------------
    def loss_fn(self, params: Sequence[jnp.ndarray], x, y):
        return L.softmax_xent(self.forward(list(params), x), y)

    def step(self, params: Sequence[jnp.ndarray], x, y):
        loss, grads = jax.value_and_grad(self.loss_fn)(list(params), x, y)
        return (loss, *grads)

    def evaluate(self, params: Sequence[jnp.ndarray], x, y):
        logits = self.forward(list(params), x)
        return (L.softmax_xent(logits, y), L.ncorrect(logits, y))


# ---------------------------------------------------------------------------
# CNNs
# ---------------------------------------------------------------------------


def build_mnist_dnn(rng: np.random.Generator) -> ModelSpec:
    dims = [784, 300, 100, 10]
    params = []
    for i, (a, b) in enumerate(zip(dims, dims[1:])):
        params.append(Param(f"fc{i+1}_w", L.he_fc(rng, a, b), "fc"))
        params.append(Param(f"fc{i+1}_b", L.zeros(b), "fc"))

    def forward(p, x):
        h = x.reshape(x.shape[0], -1)
        for i in range(0, len(p) - 2, 2):
            h = jax.nn.relu(h @ p[i] + p[i + 1])
        return h @ p[-2] + p[-1]

    return ModelSpec("mnist_dnn", params, forward, (28, 28, 1), "f32", 1, 10, 100)


def build_mnist_cnn(rng: np.random.Generator) -> ModelSpec:
    params = [
        Param("conv1_w", L.he_conv(rng, 5, 5, 1, 16), "conv"),
        Param("conv1_b", L.zeros(16), "conv"),
        Param("conv2_w", L.he_conv(rng, 5, 5, 16, 32), "conv"),
        Param("conv2_b", L.zeros(32), "conv"),
        Param("fc1_w", L.he_fc(rng, 7 * 7 * 32, 128), "fc"),
        Param("fc1_b", L.zeros(128), "fc"),
        Param("fc2_w", L.he_fc(rng, 128, 10), "fc"),
        Param("fc2_b", L.zeros(10), "fc"),
    ]

    def forward(p, x):
        h = L.maxpool2(jax.nn.relu(L.conv2d(x, p[0]) + p[1]))
        h = L.maxpool2(jax.nn.relu(L.conv2d(h, p[2]) + p[3]))
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.relu(h @ p[4] + p[5])
        return h @ p[6] + p[7]

    return ModelSpec("mnist_cnn", params, forward, (28, 28, 1), "f32", 1, 10, 100)


def build_cifar_cnn(rng: np.random.Generator) -> ModelSpec:
    """Caffe cifar10-quick-like: 3 conv (5x5) + 1 FC + 10-softmax, ~0.36MB."""
    params = [
        Param("conv1_w", L.he_conv(rng, 5, 5, 3, 32), "conv"),
        Param("conv1_b", L.zeros(32), "conv"),
        Param("conv2_w", L.he_conv(rng, 5, 5, 32, 32), "conv"),
        Param("conv2_b", L.zeros(32), "conv"),
        Param("conv3_w", L.he_conv(rng, 5, 5, 32, 64), "conv"),
        Param("conv3_b", L.zeros(64), "conv"),
        Param("fc_w", L.he_fc(rng, 4 * 4 * 64, 10), "fc"),
        Param("fc_b", L.zeros(10), "fc"),
    ]

    def forward(p, x):
        h = jax.nn.relu(L.maxpool2(L.conv2d(x, p[0]) + p[1]))  # pool-then-relu (Caffe quick)
        h = L.maxpool2(jax.nn.relu(L.conv2d(h, p[2]) + p[3]))
        h = L.maxpool2(jax.nn.relu(L.conv2d(h, p[4]) + p[5]))
        h = h.reshape(h.shape[0], -1)
        return h @ p[6] + p[7]

    return ModelSpec("cifar_cnn", params, forward, (32, 32, 3), "f32", 1, 10, 128)


def build_alexnet_s(rng: np.random.Generator) -> ModelSpec:
    """Scaled AlexNet surrogate: 5 conv + 3 FC on 32x32 synthetic-ImageNet (100-way)."""
    params = [
        Param("conv1_w", L.he_conv(rng, 3, 3, 3, 48), "conv"),
        Param("conv1_b", L.zeros(48), "conv"),
        Param("conv2_w", L.he_conv(rng, 3, 3, 48, 96), "conv"),
        Param("conv2_b", L.zeros(96), "conv"),
        Param("conv3_w", L.he_conv(rng, 3, 3, 96, 96), "conv"),
        Param("conv3_b", L.zeros(96), "conv"),
        Param("conv4_w", L.he_conv(rng, 3, 3, 96, 64), "conv"),
        Param("conv4_b", L.zeros(64), "conv"),
        Param("conv5_w", L.he_conv(rng, 3, 3, 64, 64), "conv"),
        Param("conv5_b", L.zeros(64), "conv"),
        Param("fc1_w", L.he_fc(rng, 4 * 4 * 64, 512), "fc"),
        Param("fc1_b", L.zeros(512), "fc"),
        Param("fc2_w", L.he_fc(rng, 512, 256), "fc"),
        Param("fc2_b", L.zeros(256), "fc"),
        Param("fc3_w", L.he_fc(rng, 256, 100), "fc"),
        Param("fc3_b", L.zeros(100), "fc"),
    ]

    def forward(p, x):
        h = L.maxpool2(jax.nn.relu(L.conv2d(x, p[0]) + p[1]))  # 16
        h = L.maxpool2(jax.nn.relu(L.conv2d(h, p[2]) + p[3]))  # 8
        h = jax.nn.relu(L.conv2d(h, p[4]) + p[5])
        h = jax.nn.relu(L.conv2d(h, p[6]) + p[7])
        h = L.maxpool2(jax.nn.relu(L.conv2d(h, p[8]) + p[9]))  # 4
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.relu(h @ p[10] + p[11])
        h = jax.nn.relu(h @ p[12] + p[13])
        return h @ p[14] + p[15]

    return ModelSpec("alexnet_s", params, forward, (32, 32, 3), "f32", 1, 100, 64)


def _res_block(rng, params, tag, cin, cout, stride):
    """Plain (3x3, 3x3) residual block, norm-free with scaled init."""
    params.append(Param(f"{tag}_c1_w", L.he_conv(rng, 3, 3, cin, cout), "conv"))
    params.append(Param(f"{tag}_c1_b", L.zeros(cout), "conv"))
    w2 = L.he_conv(rng, 3, 3, cout, cout) * 0.25  # damped second conv (fixup-style)
    params.append(Param(f"{tag}_c2_w", w2, "conv"))
    params.append(Param(f"{tag}_c2_b", L.zeros(cout), "conv"))
    if stride != 1 or cin != cout:
        params.append(Param(f"{tag}_sc_w", L.he_conv(rng, 1, 1, cin, cout), "conv"))
    return stride != 1 or cin != cout


def build_resnet18_s(rng: np.random.Generator) -> ModelSpec:
    """8 plain residual blocks (16 conv) + FC — scaled ResNet18 surrogate."""
    params = [
        Param("stem_w", L.he_conv(rng, 3, 3, 3, 32), "conv"),
        Param("stem_b", L.zeros(32), "conv"),
    ]
    plan = []  # (has_shortcut, stride)
    cfg = [(32, 32, 1), (32, 32, 1), (32, 64, 2), (64, 64, 1),
           (64, 128, 2), (128, 128, 1), (128, 128, 1), (128, 128, 1)]
    for i, (cin, cout, s) in enumerate(cfg):
        has_sc = _res_block(rng, params, f"b{i}", cin, cout, s)
        plan.append((has_sc, s))
    params.append(Param("fc_w", L.he_fc(rng, 128, 100), "fc"))
    params.append(Param("fc_b", L.zeros(100), "fc"))

    def forward(p, x):
        h = jax.nn.relu(L.conv2d(x, p[0]) + p[1])
        i = 2
        for has_sc, s in plan:
            y = jax.nn.relu(L.conv2d(h, p[i], stride=s) + p[i + 1])
            y = L.conv2d(y, p[i + 2]) + p[i + 3]
            i += 4
            sc = h
            if has_sc:
                sc = L.conv2d(h, p[i], stride=s)
                i += 1
            h = jax.nn.relu(y + sc)
        h = L.avgpool_global(h)
        return h @ p[i] + p[i + 1]

    return ModelSpec("resnet18_s", params, forward, (32, 32, 3), "f32", 1, 100, 32)


def _bottleneck(rng, params, tag, cin, cmid, cout, stride):
    params.append(Param(f"{tag}_c1_w", L.he_conv(rng, 1, 1, cin, cmid), "conv"))
    params.append(Param(f"{tag}_c1_b", L.zeros(cmid), "conv"))
    params.append(Param(f"{tag}_c2_w", L.he_conv(rng, 3, 3, cmid, cmid), "conv"))
    params.append(Param(f"{tag}_c2_b", L.zeros(cmid), "conv"))
    w3 = L.he_conv(rng, 1, 1, cmid, cout) * 0.25
    params.append(Param(f"{tag}_c3_w", w3, "conv"))
    params.append(Param(f"{tag}_c3_b", L.zeros(cout), "conv"))
    if stride != 1 or cin != cout:
        params.append(Param(f"{tag}_sc_w", L.he_conv(rng, 1, 1, cin, cout), "conv"))
    return stride != 1 or cin != cout


def build_resnet50_s(rng: np.random.Generator) -> ModelSpec:
    """6 bottleneck blocks (18 conv) + FC — scaled ResNet50 surrogate."""
    params = [
        Param("stem_w", L.he_conv(rng, 3, 3, 3, 32), "conv"),
        Param("stem_b", L.zeros(32), "conv"),
    ]
    cfg = [(32, 16, 64, 1), (64, 16, 64, 1), (64, 32, 128, 2),
           (128, 32, 128, 1), (128, 64, 256, 2), (256, 64, 256, 1)]
    plan = []
    for i, (cin, cmid, cout, s) in enumerate(cfg):
        has_sc = _bottleneck(rng, params, f"b{i}", cin, cmid, cout, s)
        plan.append((has_sc, s))
    params.append(Param("fc_w", L.he_fc(rng, 256, 100), "fc"))
    params.append(Param("fc_b", L.zeros(100), "fc"))

    def forward(p, x):
        h = jax.nn.relu(L.conv2d(x, p[0]) + p[1])
        i = 2
        for has_sc, s in plan:
            y = jax.nn.relu(L.conv2d(h, p[i], stride=s) + p[i + 1])
            y = jax.nn.relu(L.conv2d(y, p[i + 2]) + p[i + 3])
            y = L.conv2d(y, p[i + 4]) + p[i + 5]
            i += 6
            sc = h
            if has_sc:
                sc = L.conv2d(h, p[i], stride=s)
                i += 1
            h = jax.nn.relu(y + sc)
        h = L.avgpool_global(h)
        return h @ p[i] + p[i + 1]

    return ModelSpec("resnet50_s", params, forward, (32, 32, 3), "f32", 1, 100, 32)


# ---------------------------------------------------------------------------
# DNN (speech) and LSTM / transformer (language)
# ---------------------------------------------------------------------------


def _build_dnn(name, rng, dims, batch) -> ModelSpec:
    params = []
    for i, (a, b) in enumerate(zip(dims, dims[1:])):
        params.append(Param(f"fc{i+1}_w", L.he_fc(rng, a, b), "fc"))
        params.append(Param(f"fc{i+1}_b", L.zeros(b), "fc"))

    def forward(p, x):
        h = x
        for i in range(0, len(p) - 2, 2):
            h = jax.nn.relu(h @ p[i] + p[i + 1])
        return h @ p[-2] + p[-1]

    return ModelSpec(name, params, forward, (dims[0],), "f32", 1, dims[-1], batch)


def build_bn50_dnn(rng: np.random.Generator) -> ModelSpec:
    """Paper-exact BN50 DNN: 440-1024x4-5999 (6 FC layers)."""
    return _build_dnn("bn50_dnn", rng, [440, 1024, 1024, 1024, 1024, 1024, 5999], 256)


def build_bn50_dnn_s(rng: np.random.Generator) -> ModelSpec:
    """Scaled BN50 DNN for fast harnesses: 440-512x4-1500."""
    return _build_dnn("bn50_dnn_s", rng, [440, 512, 512, 512, 512, 512, 1500], 128)


def build_char_lstm(rng: np.random.Generator, seq_len: int = 50) -> ModelSpec:
    """Karpathy char-rnn shape: 2 LSTM (67-512, 512-512) + FC 512-67."""
    h1 = h2 = 512
    wx1, wh1, b1 = L.lstm_init(rng, VOCAB, h1)
    wx2, wh2, b2 = L.lstm_init(rng, h1, h2)
    params = [
        Param("lstm1_wx", wx1, "lstm"),
        Param("lstm1_wh", wh1, "lstm"),
        Param("lstm1_b", b1, "lstm"),
        Param("lstm2_wx", wx2, "lstm"),
        Param("lstm2_wh", wh2, "lstm"),
        Param("lstm2_b", b2, "lstm"),
        Param("fc_w", L.he_fc(rng, h2, VOCAB, gain=1.0), "fc"),
        Param("fc_b", L.zeros(VOCAB), "fc"),
    ]

    def forward(p, x):
        h = jax.nn.one_hot(x, VOCAB, dtype=jnp.float32)
        h = L.lstm_layer(h, p[0], p[1], p[2])
        h = L.lstm_layer(h, p[3], p[4], p[5])
        return h @ p[6] + p[7]

    return ModelSpec(
        "char_lstm", params, forward, (seq_len,), "i32", 2, VOCAB, 10, seq_len
    )


def build_transformer(
    rng: np.random.Generator,
    d_model: int = 256,
    nlayers: int = 4,
    nheads: int = 4,
    d_ff: int = 1024,
    seq_len: int = 96,
    batch: int = 4,
    name: str = "transformer",
) -> ModelSpec:
    """Causal char transformer LM — the end-to-end driver model."""
    params = [
        Param("embed", L.he_fc(rng, VOCAB, d_model, gain=1.0), "embed"),
        Param("pos", (rng.standard_normal((seq_len, d_model)) * 0.02).astype(np.float32), "embed"),
    ]
    for i in range(nlayers):
        t = f"blk{i}"
        params += [
            Param(f"{t}_ln1_g", np.ones((d_model,), np.float32), "fc"),
            Param(f"{t}_ln1_b", L.zeros(d_model), "fc"),
            Param(f"{t}_wq", L.he_fc(rng, d_model, d_model, gain=1.0), "fc"),
            Param(f"{t}_wk", L.he_fc(rng, d_model, d_model, gain=1.0), "fc"),
            Param(f"{t}_wv", L.he_fc(rng, d_model, d_model, gain=1.0), "fc"),
            Param(f"{t}_wo", L.he_fc(rng, d_model, d_model, gain=1.0) * 0.5, "fc"),
            Param(f"{t}_ln2_g", np.ones((d_model,), np.float32), "fc"),
            Param(f"{t}_ln2_b", L.zeros(d_model), "fc"),
            Param(f"{t}_w1", L.he_fc(rng, d_model, d_ff), "fc"),
            Param(f"{t}_b1", L.zeros(d_ff), "fc"),
            Param(f"{t}_w2", L.he_fc(rng, d_ff, d_model, gain=1.0) * 0.5, "fc"),
            Param(f"{t}_b2", L.zeros(d_model), "fc"),
        ]
    params += [
        Param("lnf_g", np.ones((d_model,), np.float32), "fc"),
        Param("lnf_b", L.zeros(d_model), "fc"),
        Param("head_w", L.he_fc(rng, d_model, VOCAB, gain=1.0), "fc"),
        Param("head_b", L.zeros(VOCAB), "fc"),
    ]

    def forward(p, x):
        h = p[0][x] + p[1][None, : x.shape[1], :]
        i = 2
        for _ in range(nlayers):
            ln1 = L.layer_norm(h, p[i], p[i + 1])
            h = h + L.causal_attention(ln1, p[i + 2], p[i + 3], p[i + 4], p[i + 5], nheads)
            ln2 = L.layer_norm(h, p[i + 6], p[i + 7])
            h = h + jax.nn.relu(ln2 @ p[i + 8] + p[i + 9]) @ p[i + 10] + p[i + 11]
            i += 12
        h = L.layer_norm(h, p[i], p[i + 1])
        return h @ p[i + 2] + p[i + 3]

    return ModelSpec(name, params, forward, (seq_len,), "i32", 2, VOCAB, batch, seq_len)


BUILDERS = {
    "mnist_dnn": build_mnist_dnn,
    "mnist_cnn": build_mnist_cnn,
    "cifar_cnn": build_cifar_cnn,
    "alexnet_s": build_alexnet_s,
    "resnet18_s": build_resnet18_s,
    "resnet50_s": build_resnet50_s,
    "bn50_dnn": build_bn50_dnn,
    "bn50_dnn_s": build_bn50_dnn_s,
    "char_lstm": build_char_lstm,
    "transformer": build_transformer,
}

# Models exported by default (`make artifacts`). bn50_dnn (full, 43MB) and
# resnet50_s can be added with `python -m compile.aot --models all`.
DEFAULT_EXPORT = [
    "mnist_dnn",
    "mnist_cnn",
    "cifar_cnn",
    "alexnet_s",
    "resnet18_s",
    "bn50_dnn_s",
    "char_lstm",
    "transformer",
]


def build(name: str, seed: int = 7) -> ModelSpec:
    rng = np.random.default_rng(seed)
    return BUILDERS[name](rng)
