"""L2 building blocks: initializers and layer primitives in pure jnp/lax.

Every model in ``model.py`` is expressed over a flat *list* of parameter
arrays (manifest order) so the AOT-exported HLO has the calling convention

    step(*params, x, y) -> (loss, *grads)

that the rust runtime (rust/src/runtime/step.rs) expects. No pytrees cross
the interchange boundary.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

# ---------------------------------------------------------------------------
# Initializers (numpy so init bins are bit-reproducible across jax versions)
# ---------------------------------------------------------------------------


def he_conv(rng: np.random.Generator, kh, kw, cin, cout):
    """He-normal init for an HWIO conv kernel."""
    std = math.sqrt(2.0 / (kh * kw * cin))
    return (rng.standard_normal((kh, kw, cin, cout)) * std).astype(np.float32)


def he_fc(rng: np.random.Generator, fan_in, fan_out, gain=2.0):
    std = math.sqrt(gain / fan_in)
    return (rng.standard_normal((fan_in, fan_out)) * std).astype(np.float32)


def zeros(*shape):
    return np.zeros(shape, dtype=np.float32)


def lstm_init(rng: np.random.Generator, in_dim, hidden):
    """Wx (in,4H), Wh (H,4H), b (4H) with forget-gate bias 1."""
    wx = he_fc(rng, in_dim, 4 * hidden, gain=1.0)
    wh = he_fc(rng, hidden, 4 * hidden, gain=1.0)
    b = np.zeros((4 * hidden,), dtype=np.float32)
    b[hidden : 2 * hidden] = 1.0  # forget gate
    return wx, wh, b


# ---------------------------------------------------------------------------
# Forward primitives
# ---------------------------------------------------------------------------

DN_NHWC = ("NHWC", "HWIO", "NHWC")


def conv2d(x, w, stride=1, padding="SAME"):
    return lax.conv_general_dilated(
        x, w, (stride, stride), padding, dimension_numbers=DN_NHWC
    )


def maxpool2(x):
    """2x2 max pool, stride 2, NHWC."""
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def avgpool_global(x):
    """Global average pool NHWC -> NC."""
    return jnp.mean(x, axis=(1, 2))


def lstm_layer(x, wx, wh, b):
    """x: (B, T, in) -> (B, T, H). Scan over time with (h, c) carry."""
    hidden = wh.shape[0]
    bsz = x.shape[0]

    def cell(carry, xt):
        h, c = carry
        z = xt @ wx + h @ wh + b
        i, f, g, o = jnp.split(z, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    init = (
        jnp.zeros((bsz, hidden), x.dtype),
        jnp.zeros((bsz, hidden), x.dtype),
    )
    _, hs = lax.scan(cell, init, jnp.swapaxes(x, 0, 1))
    return jnp.swapaxes(hs, 0, 1)


def layer_norm(x, gamma, beta, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return gamma * (x - mu) * lax.rsqrt(var + eps) + beta


def causal_attention(x, wq, wk, wv, wo, nheads):
    """Multi-head causal self-attention; x (B,T,D)."""
    b, t, d = x.shape
    hd = d // nheads

    def split(z):
        return z.reshape(b, t, nheads, hd).transpose(0, 2, 1, 3)

    q, k, v = split(x @ wq), split(x @ wk), split(x @ wv)
    att = (q @ k.transpose(0, 1, 3, 2)) / math.sqrt(hd)
    causal = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(causal, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, d)
    return out @ wo


def softmax_xent(logits, labels):
    """Mean cross-entropy; logits (..., C), labels (...) int32."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def ncorrect(logits, labels):
    """Top-1 correct count as f32 (crosses the HLO boundary as f32)."""
    return jnp.sum((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
