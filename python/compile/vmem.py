"""TPU resource estimate for the L1 Pallas compression kernels.

interpret=True gives CPU-numpy execution, so real-TPU performance is
*estimated* here from the BlockSpec geometry (DESIGN.md §Hardware-Adaptation):
VMEM footprint per grid step, arithmetic intensity, and the resulting
HBM-bandwidth-bound roofline time. The kernels are elementwise/reduction
(VPU work, no MXU), so the bound is memory bandwidth, not FLOPs.

Usage:  python -m compile.vmem [--lt 50 500] [--block-bins 8]
"""

from __future__ import annotations

import argparse

# TPU v4-ish reference numbers (per core), used only for the printed estimate.
VMEM_BYTES = 16 * 2**20  # ~16 MiB usable VMEM
HBM_BW = 1.2e12  # 1.2 TB/s
VPU_FLOPS = 4e12  # vector unit, f32


def kernel_report(n: int, lt: int, block_bins: int, dtype_bytes: int = 4) -> dict:
    nbins = -(-n // lt)
    # binmax kernel: reads one (block_bins, lt) tile of G, writes block_bins.
    binmax_tile = block_bins * lt * dtype_bytes + block_bins * dtype_bytes
    # select kernel: reads G, H tiles + gmax, writes mask tile.
    select_tile = (3 * block_bins * lt + block_bins) * dtype_bytes
    # whole-layer HBM traffic: binmax reads G once; select reads G,H and
    # writes mask; the jnp epilogue (ternarize + residue) reads mask,G and
    # writes gq,residue — XLA fuses it with select's consumer on TPU.
    hbm_bytes = (
        n * dtype_bytes  # binmax read
        + 3 * n * dtype_bytes  # select read G,H write mask
        + 4 * n * dtype_bytes  # epilogue read mask,G write gq,residue
    )
    flops = 3 * n  # abs+max, abs+cmp, mul-add epilogue (approx, per element)
    roofline_s = max(hbm_bytes / HBM_BW, flops / VPU_FLOPS)
    return {
        "n": n,
        "lt": lt,
        "nbins": nbins,
        "block_bins": block_bins,
        "binmax_tile_bytes": binmax_tile,
        "select_tile_bytes": select_tile,
        "vmem_fits": max(binmax_tile, select_tile) < VMEM_BYTES,
        "vmem_utilization": max(binmax_tile, select_tile) / VMEM_BYTES,
        "hbm_bytes": hbm_bytes,
        "arith_intensity_flops_per_byte": flops / hbm_bytes,
        "roofline_us": roofline_s * 1e6,
        "bound": "HBM-bandwidth" if hbm_bytes / HBM_BW > flops / VPU_FLOPS else "VPU",
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--lt", type=int, nargs="+", default=[50, 500])
    ap.add_argument("--block-bins", type=int, default=8)
    ap.add_argument("--sizes", type=int, nargs="+", default=[25_600, 1_048_576, 16_777_216])
    args = ap.parse_args()

    print(f"{'n':>10} {'L_T':>6} {'tile KiB':>9} {'VMEM %':>7} {'HBM MiB':>8} {'roofline':>10}  bound")
    for n in args.sizes:
        for lt in args.lt:
            r = kernel_report(n, lt, args.block_bins)
            print(
                f"{r['n']:>10} {r['lt']:>6} "
                f"{max(r['binmax_tile_bytes'], r['select_tile_bytes'])/1024:>9.1f} "
                f"{100*r['vmem_utilization']:>6.2f}% "
                f"{r['hbm_bytes']/2**20:>8.2f} "
                f"{r['roofline_us']:>8.1f}us  {r['bound']}"
            )
    print(
        "\nAll tiles fit VMEM with huge headroom; the kernel is HBM-bandwidth"
        "\nbound at ~8 f32 accesses per element — i.e. compression costs about"
        "\nas much as two or three elementwise passes over the gradient, exactly"
        "\nthe paper's 'computationally friendly, O(N), localized' requirement."
    )


if __name__ == "__main__":
    main()
