"""AOT export: lower the L2 model zoo (and the L1 compression graph) to HLO
text artifacts the rust runtime loads via PJRT.

Per model this writes:
  artifacts/<name>.step.hlo.txt   step(*params, x, y) -> (loss, *grads)
  artifacts/<name>.eval.hlo.txt   eval(*params, x, y) -> (loss, ncorrect)
  artifacts/<name>.init.bin       initial params, raw little-endian f32,
                                  concatenated in manifest order
plus once:
  artifacts/manifest.json         model/param layout the rust side parses
  artifacts/golden_adacomp.json   golden vectors: ref.py outputs on fixed
                                  inputs; rust/tests cross-check bit-for-bit
  artifacts/adacomp_n{N}_lt{L}.hlo.txt  standalone L1 compression graphs
                                  (Pallas kernels lowered to HLO) for the
                                  fused-on-accelerator example

Interchange is HLO *text*: jax 0.8 serialized protos use 64-bit instruction
ids that xla_extension 0.5.1 rejects; the text parser reassigns ids.
See /opt/xla-example/README.md and DESIGN.md §Interchange.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import adacomp as K
from .kernels import ref

# Standalone compression graphs exported for the fused-accelerator example:
# (layer length, L_T) pairs covering the cifar_cnn layers at paper defaults.
ADACOMP_EXPORTS = [(2400, 50), (25600, 50), (51200, 50), (10240, 500)]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_of(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_variants(spec: M.ModelSpec) -> list:
    """Batch sizes exported per model: the default and its halvings down to 1
    (per-learner batch = super-batch / N under strong scaling), plus larger
    super-batches for cifar_cnn (Fig 7a sweeps minibatch 128..2048)."""
    sizes = set()
    b = spec.batch
    while b >= 1:
        sizes.add(b)
        b //= 2
    if spec.name == "cifar_cnn":
        sizes.update([256, 512, 1024, 2048])
    return sorted(sizes)


def export_model(spec: M.ModelSpec, outdir: str) -> dict:
    """Lower step (per batch variant) + eval, write init bin, return the
    manifest entry."""
    p_specs = [spec_of(p.value.shape, jnp.float32) for p in spec.params]
    x_dtype = jnp.float32 if spec.x_dtype == "f32" else jnp.int32

    def step(*args):
        return spec.step(list(args[: len(p_specs)]), args[-2], args[-1])

    def evaluate(*args):
        return spec.evaluate(list(args[: len(p_specs)]), args[-2], args[-1])

    def specs_for(b):
        x_spec = spec_of((b, *spec.x_shape), x_dtype)
        y_shape = (b,) if spec.y_ndim == 1 else (b, spec.seq_len)
        return x_spec, spec_of(y_shape, jnp.int32)

    step_hlos = {}
    for b in batch_variants(spec):
        x_spec, y_spec = specs_for(b)
        hlo = to_hlo_text(jax.jit(step).lower(*p_specs, x_spec, y_spec))
        path = f"{spec.name}.step.b{b}.hlo.txt"
        with open(os.path.join(outdir, path), "w") as f:
            f.write(hlo)
        step_hlos[str(b)] = path

    x_spec, y_spec = specs_for(spec.batch)
    y_shape = y_spec.shape
    step_hlo = open(os.path.join(outdir, step_hlos[str(spec.batch)])).read()
    eval_hlo = to_hlo_text(jax.jit(evaluate).lower(*p_specs, x_spec, y_spec))

    step_path = step_hlos[str(spec.batch)]
    eval_path = f"{spec.name}.eval.hlo.txt"
    with open(os.path.join(outdir, eval_path), "w") as f:
        f.write(eval_hlo)

    init_path = f"{spec.name}.init.bin"
    flat = np.concatenate([p.value.reshape(-1) for p in spec.params]).astype("<f4")
    flat.tofile(os.path.join(outdir, init_path))

    nparams = int(sum(p.value.size for p in spec.params))
    print(
        f"  {spec.name}: {len(spec.params)} tensors, {nparams} params, "
        f"batch {spec.batch}, step hlo {len(step_hlo)//1024}KB"
    )
    return {
        "name": spec.name,
        "step_hlo": step_path,
        "step_hlos": step_hlos,
        "eval_hlo": eval_path,
        "init_bin": init_path,
        "batch": spec.batch,
        "seq_len": spec.seq_len,
        "x_shape": list((spec.batch, *spec.x_shape)),
        "x_dtype": spec.x_dtype,
        "y_shape": list(y_shape),
        "num_classes": spec.num_classes,
        "num_params": nparams,
        "params": [
            {
                "name": p.name,
                "shape": list(p.value.shape),
                "kind": p.kind,
                "lt": p.lt,
            }
            for p in spec.params
        ],
    }


def export_adacomp_graphs(outdir: str) -> list:
    """Lower the L1 Pallas compression (gq, residue) graphs to HLO."""
    entries = []
    for n, lt in ADACOMP_EXPORTS:

        def compress(g, h, lt=lt):
            gq, residue, _, _, scale = K.adacomp_compress(g, h, lt)
            return (gq, residue, scale)

        s = spec_of((n,), jnp.float32)
        hlo = to_hlo_text(jax.jit(compress).lower(s, s))
        path = f"adacomp_n{n}_lt{lt}.hlo.txt"
        with open(os.path.join(outdir, path), "w") as f:
            f.write(hlo)
        entries.append({"n": n, "lt": lt, "hlo": path})
        print(f"  adacomp n={n} lt={lt}: {len(hlo)//1024}KB")
    return entries


def export_golden(outdir: str) -> None:
    """Golden vectors for the rust AdaComp implementation (bit-exact contract)."""
    rng = np.random.default_rng(1234)
    cases = []
    for n, lt, gscale in [
        (137, 10, 1.0),
        (500, 50, 0.01),
        (1024, 500, 3.0),  # single partial-ish bin regime
        (50, 50, 1.0),  # exactly one bin
        (49, 50, 1.0),  # single short bin
        (300, 7, 0.5),  # lt does not divide n
    ]:
        g = (rng.standard_normal(n) * gscale).astype(np.float32)
        dw = (rng.standard_normal(n) * gscale * 0.3).astype(np.float32)
        # zero out a whole bin sometimes to exercise the gmax>0 guard
        if n >= 2 * lt:
            g[:lt] = 0.0
            dw[:lt] = 0.0
        h = g + dw
        gq, residue, mask, gmax, scale = ref.adacomp_compress(
            jnp.asarray(g), jnp.asarray(h), lt
        )
        cases.append(
            {
                "n": n,
                "lt": lt,
                "g": [float(v) for v in g],
                "h": [float(v) for v in h],
                "gq": [float(v) for v in np.asarray(gq)],
                "residue": [float(v) for v in np.asarray(residue)],
                "mask": [int(v) for v in np.asarray(mask)],
                "gmax": [float(v) for v in np.asarray(gmax)],
                "scale": float(scale),
            }
        )
    with open(os.path.join(outdir, "golden_adacomp.json"), "w") as f:
        json.dump({"cases": cases}, f)
    print(f"  golden_adacomp.json: {len(cases)} cases")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--models",
        default="default",
        help="comma list, or 'default' (fast set) or 'all' (adds bn50_dnn, resnet50_s)",
    )
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--skip-golden", action="store_true")
    args = ap.parse_args()

    names = {
        "default": M.DEFAULT_EXPORT,
        "all": list(M.BUILDERS),
    }.get(args.models, [s for s in args.models.split(",") if s])

    os.makedirs(args.out, exist_ok=True)
    manifest = {"seed": args.seed, "models": {}}
    print(f"exporting {len(names)} models to {args.out}")
    for name in names:
        spec = M.build(name, seed=args.seed)
        manifest["models"][name] = export_model(spec, args.out)

    manifest["adacomp_graphs"] = export_adacomp_graphs(args.out)
    if not args.skip_golden:
        export_golden(args.out)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("manifest.json written")


if __name__ == "__main__":
    main()
